//! TCP server: request router over the collection registry, fronted by
//! either the bounded thread-per-connection loop (the oracle, default)
//! or the epoll reactor (`--server-mode reactor`, see
//! [`crate::coordinator::reactor`]). Both front-ends call the same
//! [`ServiceState::handle_traced`] router and produce byte-identical
//! responses.

use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::coding::CodingParams;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::durability::{DurabilityConfig, FsyncPolicy};
use crate::coordinator::maintenance::{Maintenance, MaintenanceConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::obs;
use crate::coordinator::protocol::{self, Request, Response};
use crate::coordinator::registry::{
    Collection, CollectionOptions, CollectionSpec, Registry, RegistryConfig, DEFAULT_COLLECTION,
};
use crate::lsh::IndexConfig;
use crate::coordinator::store::SketchStore;
use crate::estimator::CollisionEstimator;
use crate::projection::Projector;
use crate::scan::EpochConfig;

/// Connection front-end selection (`--server-mode`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ServerMode {
    /// Blocking thread-per-connection loop: one OS thread per client,
    /// the correctness oracle and the default.
    #[default]
    Threads,
    /// Event-driven epoll reactor: every connection multiplexed onto
    /// one thread, with pipelining, request coalescing, and
    /// write-buffer backpressure. Linux x86_64/aarch64 only.
    Reactor,
}

impl std::str::FromStr for ServerMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "threads" => Ok(ServerMode::Threads),
            "reactor" => Ok(ServerMode::Reactor),
            other => anyhow::bail!("unknown server mode {other:?} (expected threads|reactor)"),
        }
    }
}

impl ServerMode {
    pub fn label(&self) -> &'static str {
        match self {
            ServerMode::Threads => "threads",
            ServerMode::Reactor => "reactor",
        }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    /// Connection front-end: blocking threads (default) or the epoll
    /// reactor. Responses are byte-identical across modes; only
    /// scalability (and the aggregate batching counters) differ.
    pub server_mode: ServerMode,
    /// Reactor sharding (`--reactor-threads`): bind this many
    /// SO_REUSEPORT listeners, each driven by its own epoll loop
    /// thread. 0 (the default here; the CLI defaults to
    /// `min(4, cores)`) keeps the PR 8 single loop on a normally-bound
    /// listener. Ignored in thread mode.
    pub reactor_threads: usize,
    /// Worker-pool size for off-loop execution of fused bulk runs
    /// (`--reactor-workers`); 0 (default) executes them inline on the
    /// loop thread. Ignored in thread mode.
    pub reactor_workers: usize,
    /// Cooperative shutdown flag for the reactor front-end: when some
    /// other thread stores `true`, every loop closes its connections,
    /// workers join, and `serve` returns `Ok`. `None` (default) runs
    /// until the listener errors, as thread mode always does.
    pub shutdown: Option<Arc<std::sync::atomic::AtomicBool>>,
    /// Coding of the `default` collection (the one legacy no-namespace
    /// requests hit). Further collections are created at runtime.
    pub coding: CodingParams,
    pub batcher: BatcherConfig,
    /// Ingest-epoch drain/compaction policy for every collection arena.
    pub epoch: EpochConfig,
    /// Legacy single-collection persistence for `default` only
    /// (`--snapshot`/`--wal-dir`); mutually exclusive with `data_dir`.
    pub durability: Option<DurabilityConfig>,
    /// Registry root: every collection durable under
    /// `<data_dir>/<name>/{snap,wal}` + a CRC-checked `MANIFEST`.
    pub data_dir: Option<PathBuf>,
    /// WAL fsync policy for `data_dir`-mode collections.
    pub fsync: FsyncPolicy,
    /// Logged rows between automatic checkpoints for `data_dir`-mode
    /// collections (legacy durability carries its own).
    pub checkpoint_every: u64,
    /// Background drain/checkpoint thread cadence.
    pub maintenance: MaintenanceConfig,
    /// Concurrent-connection cap; over-limit connections get one clean
    /// `Error` frame and are closed. 0 = unlimited.
    pub max_conns: usize,
    /// `host:port` for the Prometheus-style `GET /metrics` listener;
    /// `None` leaves exposition to the `MetricsText` protocol request.
    pub metrics_addr: Option<String>,
    /// Log threshold (`error|warn|info|debug`); `None` defers to the
    /// `CRP_LOG` environment variable, then the `info` default. The
    /// threshold is process-global (shared stderr, shared gate): when
    /// several servers run in one process, the last `serve()` to set a
    /// level wins for all of them — see `obs::log` module docs.
    pub log_level: Option<String>,
    /// Requests at least this slow end-to-end (µs) emit one structured
    /// slow-query line; 0 disables.
    pub slow_query_us: u64,
    /// Every Nth request emits a debug-level trace line with its stage
    /// breakdown; 0 disables.
    pub trace_sample: u64,
    /// Read/write timeout applied to accepted data-path connections
    /// (`--conn-timeout`); `None` (the default) lets idle clients sit
    /// forever. Timed-out connections close with a debug log line,
    /// exactly like a client hangup.
    pub conn_timeout: Option<std::time::Duration>,
    /// Run as a read-only replica of the primary at this `host:port`
    /// (`--replicate-from`). Mutually exclusive with durability — the
    /// primary owns the durable state; the replica keeps everything in
    /// memory and re-bootstraps over the wire.
    pub replicate_from: Option<String>,
    /// Replication lag cap in bytes (`--repl-lag-cap`). On a primary:
    /// checkpoints stop retaining WAL segments for a replica once its
    /// backlog exceeds this (the replica re-bootstraps instead). On a
    /// replica: `/readyz` reports 503 while lag sits above it.
    pub repl_lag_cap: u64,
    /// Replica poll interval while caught up.
    pub repl_poll: std::time::Duration,
    /// First reconnect backoff delay after stream loss (doubles,
    /// jittered, up to `repl_backoff_max`).
    pub repl_backoff_min: std::time::Duration,
    /// Reconnect backoff ceiling.
    pub repl_backoff_max: std::time::Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7474".to_string(),
            server_mode: ServerMode::default(),
            reactor_threads: 0,
            reactor_workers: 0,
            shutdown: None,
            coding: CodingParams::new(crate::coding::Scheme::TwoBit, 0.75),
            batcher: BatcherConfig::default(),
            epoch: EpochConfig::default(),
            durability: None,
            data_dir: None,
            fsync: FsyncPolicy::Os,
            checkpoint_every: 100_000,
            maintenance: MaintenanceConfig::default(),
            max_conns: 1024,
            metrics_addr: None,
            log_level: None,
            slow_query_us: 0,
            trace_sample: 0,
            conn_timeout: None,
            replicate_from: None,
            repl_lag_cap: crate::coordinator::durability::DEFAULT_REPL_LAG_CAP,
            repl_poll: std::time::Duration::from_millis(50),
            repl_backoff_min: std::time::Duration::from_millis(100),
            repl_backoff_max: std::time::Duration::from_secs(5),
        }
    }
}

/// Shared service state: the collection registry plus direct handles to
/// the `default` collection (which always exists and serves every
/// legacy no-namespace request).
pub struct ServiceState {
    pub registry: Arc<Registry>,
    /// The `default` collection (back-compat accessors below alias it).
    pub default: Arc<Collection>,
    /// `default`'s store.
    pub store: Arc<SketchStore>,
    /// `default`'s estimator.
    pub estimator: CollisionEstimator,
    /// `default`'s sketch width.
    pub k: usize,
    pub metrics: Arc<Metrics>,
    /// Slow-query threshold and trace-sampling state.
    pub obs: obs::ObsConfig,
    /// The most recent slow queries, served over `Request::SlowQueries`.
    pub slow_ring: obs::SlowQueryRing,
    /// Replication posture when serving as a replica
    /// (`--replicate-from`); `None` on a primary. Gates writes, feeds
    /// the lag gauges, and answers `/readyz`.
    pub replica: Option<Arc<crate::coordinator::replication::ReplicaState>>,
    /// Read/write timeout for accepted connections (`--conn-timeout`).
    conn_timeout: Option<std::time::Duration>,
    /// Lag cap applied to every durable collection's segment retention
    /// (and to collections created later at runtime).
    repl_lag_cap: u64,
    /// The replica-side applier thread; dropping the state stops it.
    _replicator: Option<crate::coordinator::replication::Replicator>,
    /// Background drain/checkpoint thread; its `Drop` is the graceful-
    /// shutdown flush.
    _maintenance: Maintenance,
}

impl ServiceState {
    /// In-memory service state (no durability). Panics only if the
    /// configuration fails to open — use [`ServiceState::open`] for
    /// durable configurations.
    pub fn new(projector: Arc<Projector>, cfg: &ServerConfig) -> Arc<Self> {
        Self::open(projector, cfg).expect("opening service state")
    }

    /// Build the service state: open the registry (recovering every
    /// collection from `cfg.data_dir`'s MANIFEST, or `default` from
    /// legacy `cfg.durability`), then spawn the background maintenance
    /// thread that owns drains, compaction, and checkpoints for all of
    /// them.
    pub fn open(projector: Arc<Projector>, cfg: &ServerConfig) -> crate::Result<Arc<Self>> {
        let metrics = Arc::new(Metrics::default());
        let registry = Registry::open(
            RegistryConfig {
                root: cfg.data_dir.clone(),
                epoch: cfg.epoch.clone(),
                batcher: cfg.batcher.clone(),
                checkpoint_every: cfg.checkpoint_every,
                fsync: cfg.fsync,
            },
            metrics.clone(),
            projector,
            cfg.coding.clone(),
            cfg.durability.clone(),
        )?;
        let default = registry
            .get(DEFAULT_COLLECTION)
            .expect("registry always installs the default collection");
        // Primary-side retention: every durable collection gates
        // checkpoint segment deletion on attached replicas up to this
        // cap (collections created later get it in CreateCollection).
        for c in registry.list() {
            if let Some(d) = &c.durability {
                d.set_repl_lag_cap(cfg.repl_lag_cap);
            }
        }
        let replicator = match &cfg.replicate_from {
            Some(primary) => {
                anyhow::ensure!(
                    cfg.durability.is_none() && cfg.data_dir.is_none(),
                    "--replicate-from runs in-memory: drop --data-dir/--snapshot/--wal-dir \
                     (the primary owns the durable state; a promoted replica can be \
                     re-seeded durably later)"
                );
                Some(crate::coordinator::replication::Replicator::spawn(
                    registry.clone(),
                    crate::coordinator::replication::ReplicationConfig {
                        primary: primary.clone(),
                        poll: cfg.repl_poll,
                        backoff_min: cfg.repl_backoff_min,
                        backoff_max: cfg.repl_backoff_max,
                        lag_cap: cfg.repl_lag_cap,
                    },
                )?)
            }
            None => None,
        };
        let maintenance =
            Maintenance::spawn(registry.clone(), metrics.clone(), cfg.maintenance.clone());
        Ok(Arc::new(ServiceState {
            store: default.store.clone(),
            estimator: default.estimator.clone(),
            k: default.k,
            default,
            registry,
            metrics,
            obs: obs::ObsConfig::new(cfg.slow_query_us, cfg.trace_sample),
            slow_ring: obs::SlowQueryRing::default(),
            replica: replicator.as_ref().map(|r| r.state()),
            conn_timeout: cfg.conn_timeout,
            repl_lag_cap: cfg.repl_lag_cap,
            _replicator: replicator,
            _maintenance: maintenance,
        }))
    }

    /// Readiness for `GET /readyz`: a primary is ready once it serves
    /// (recovery happens inside [`ServiceState::open`], before the
    /// listener accepts); an active replica also needs its bootstrap
    /// finished and replication lag under the cap.
    pub fn health(&self) -> (bool, String) {
        match &self.replica {
            Some(r) if r.is_active() => {
                if r.ready() {
                    (
                        true,
                        format!("ready (replica of {}, lag {} bytes)", r.primary, r.lag_bytes()),
                    )
                } else {
                    (
                        false,
                        format!(
                            "replica of {} not ready: lag {} bytes (cap {}), {:.1}s behind",
                            r.primary,
                            r.lag_bytes(),
                            self.repl_lag_cap,
                            r.lag_seconds()
                        ),
                    )
                }
            }
            _ => (true, "ready".to_string()),
        }
    }

    /// As [`ServiceState::new`], seeding the `default` collection from
    /// a snapshot file (see [`crate::coordinator::durability::snapshot`])
    /// via one bulk restore — no per-sketch epoch-buffer trips. The
    /// snapshot's sketch shape must match the projector/coding
    /// configuration.
    pub fn with_snapshot(
        projector: Arc<Projector>,
        cfg: &ServerConfig,
        snapshot: &std::path::Path,
    ) -> crate::Result<Arc<Self>> {
        // Legacy one-shot restore: the explicit file is the whole
        // story, so strip any durability config rather than recovering
        // through it first and double-restoring (and double-counting
        // `registered`) on top.
        let cfg = ServerConfig {
            durability: None,
            data_dir: None,
            ..cfg.clone()
        };
        let state = Self::open(projector, &cfg)?;
        if snapshot.is_file() {
            let img = crate::coordinator::durability::snapshot::load(snapshot)?;
            // Stored sketches carry the width-rounded packing bits, so
            // compare against the rounded width, not the raw bit count.
            let want_bits = crate::coding::supported_width(cfg.coding.bits_per_code());
            anyhow::ensure!(
                img.rows() == 0 || (img.k == state.k && img.bits == want_bits),
                "snapshot shape (k={}, bits={}) does not match service (k={}, bits={})",
                img.k,
                img.bits,
                state.k,
                want_bits
            );
            let n = crate::coordinator::durability::snapshot::restore_into(&state.store, &img)?;
            state.metrics.registered.fetch_add(n, Ordering::Relaxed);
        }
        Ok(state)
    }

    /// Handle one request (the router). Legacy frames carry no
    /// collection and route to `default`; `Scoped` frames name one.
    pub fn handle(&self, req: Request) -> Response {
        self.handle_traced(req).0
    }

    /// As [`ServiceState::handle`], also returning the routing metadata
    /// the connection loop records (request kind, target collection,
    /// ApproxTopK candidate count).
    pub fn handle_traced(&self, req: Request) -> (Response, obs::ReqMeta) {
        let kind = obs::RequestKind::of(&req);
        let mut candidates = None;
        let (collection, resp) = match req {
            Request::Scoped { collection, inner } => {
                let resp = self.handle_in(Some(&collection), *inner, &mut candidates);
                (Some(collection), resp)
            }
            other => (None, self.handle_in(None, other, &mut candidates)),
        };
        (
            resp,
            obs::ReqMeta {
                kind,
                collection,
                candidates,
            },
        )
    }

    /// Resolve the target collection of a data-path request.
    #[allow(clippy::result_large_err)] // the Err is the wire Response itself
    fn resolve(&self, collection: Option<&str>) -> Result<Arc<Collection>, Response> {
        let name = collection.unwrap_or(DEFAULT_COLLECTION);
        self.registry.get(name).ok_or_else(|| Response::Error {
            message: format!(
                "unknown collection {name:?} (create it with `crp collection create`)"
            ),
        })
    }

    fn handle_in(
        &self,
        collection: Option<&str>,
        req: Request,
        candidates: &mut Option<u64>,
    ) -> Response {
        // An active replica serves every read but owns no writes: its
        // state is a projection of the primary's WAL, and a local
        // mutation would silently diverge (or be clobbered by the next
        // bootstrap). Reject with a redirect naming the primary.
        if let Some(r) = &self.replica {
            if r.is_active()
                && matches!(
                    req,
                    Request::Register { .. }
                        | Request::RegisterBatch { .. }
                        | Request::RegisterSparse { .. }
                        | Request::Remove { .. }
                        | Request::Persist
                        | Request::CreateCollection { .. }
                        | Request::DropCollection { .. }
                )
            {
                return Response::Error {
                    message: format!(
                        "replica is read-only; write to the primary at {} (or promote this \
                         replica with `crp promote`)",
                        r.primary
                    ),
                };
            }
        }
        match req {
            Request::Ping => Response::Pong,
            Request::Stats => self.stats(false),
            Request::StatsDetailed => self.stats(true),
            Request::MetricsText => Response::MetricsText {
                text: obs::expo::render(&self.metrics, &self.registry, self.replica.as_deref()),
            },
            Request::ReplSync {
                collection: name,
                replica,
                segment,
                offset,
            } => self.repl_sync(&name, &replica, segment, offset),
            Request::SlowQueries { max } => Response::SlowQueries {
                entries: self.slow_ring.entries(max),
            },
            Request::Promote => {
                let was_replica = self.replica.as_ref().map(|r| r.promote()).unwrap_or(false);
                if was_replica {
                    obs::log::info(
                        "crp::server",
                        "promoted to primary; writes accepted",
                        &[],
                    );
                }
                Response::Promoted { was_replica }
            }
            Request::Scoped { .. } => Response::Error {
                message: "nested Scoped request".to_string(),
            },
            Request::CreateCollection {
                name,
                scheme,
                w,
                bits,
                k,
                seed,
                checkpoint_every,
                kind,
            } => {
                let spec = CollectionSpec {
                    scheme,
                    w,
                    k: k as usize,
                    seed,
                    kind,
                };
                if bits != 0 && bits != spec.bits() {
                    return Response::Error {
                        message: format!(
                            "scheme {} at w {} packs {} bit(s)/code, not {bits}",
                            scheme.label(),
                            w,
                            spec.bits()
                        ),
                    };
                }
                let options = CollectionOptions {
                    checkpoint_every,
                    index: IndexConfig::for_shape(spec.k, spec.bits()),
                };
                match self.registry.create(&name, spec, options) {
                    Ok(c) => {
                        if let Some(d) = &c.durability {
                            d.set_repl_lag_cap(self.repl_lag_cap);
                        }
                        Response::CollectionCreated { name }
                    }
                    Err(e) => Response::Error {
                        message: format!("create collection failed: {e}"),
                    },
                }
            }
            Request::DropCollection { name } => match self.registry.drop_collection(&name) {
                Ok(existed) => Response::CollectionDropped { existed },
                Err(e) => Response::Error {
                    message: format!("drop collection failed: {e}"),
                },
            },
            Request::ListCollections => Response::Collections {
                collections: self.registry.list().iter().map(|c| c.info()).collect(),
            },
            // Legacy whole-server Persist checkpoints every durable
            // collection; the scoped form checkpoints one.
            Request::Persist => match collection {
                Some(_) => match self.resolve(collection) {
                    Ok(c) => c.persist(),
                    Err(resp) => resp,
                },
                None => match self.registry.checkpoint_all() {
                    Ok(Some((rows, wal_bytes))) => Response::Persisted { rows, wal_bytes },
                    Ok(None) => Response::Error {
                        message: "durability is not enabled (serve with --data-dir or \
                                  --snapshot/--wal-dir)"
                            .to_string(),
                    },
                    Err(e) => Response::Error {
                        message: format!("checkpoint failed: {e}"),
                    },
                },
            },
            Request::Register { id, vector } => match self.resolve(collection) {
                Ok(c) => c.register(id, vector),
                Err(resp) => resp,
            },
            Request::RegisterBatch { ids, vectors } => match self.resolve(collection) {
                Ok(c) => c.register_batch(ids, vectors),
                Err(resp) => resp,
            },
            Request::RegisterSparse { ids, csr } => match self.resolve(collection) {
                Ok(c) => {
                    // Ingest cost scales with nnz, so that is the
                    // candidates-style magnitude the slow-query line
                    // carries for sparse batches.
                    *candidates = Some(csr.nnz() as u64);
                    c.register_sparse(ids, csr)
                }
                Err(resp) => resp,
            },
            Request::Remove { id } => match self.resolve(collection) {
                Ok(c) => c.remove(id),
                Err(resp) => resp,
            },
            Request::Estimate { a, b } => match self.resolve(collection) {
                Ok(c) => c.estimate(a, b),
                Err(resp) => resp,
            },
            Request::EstimateVec { id, vector } => match self.resolve(collection) {
                Ok(c) => c.estimate_vec(id, vector),
                Err(resp) => resp,
            },
            Request::Knn { vector, n } => match self.resolve(collection) {
                Ok(c) => c.knn(vector, n),
                Err(resp) => resp,
            },
            Request::TopK { vectors, n } => match self.resolve(collection) {
                Ok(c) => c.topk(vectors, n),
                Err(resp) => resp,
            },
            Request::ApproxTopK { vectors, n, probes } => match self.resolve(collection) {
                Ok(c) => {
                    let (resp, cands) = c.approx_topk(vectors, n, probes);
                    *candidates = Some(cands);
                    resp
                }
                Err(resp) => resp,
            },
        }
    }

    /// Primary side of the replication stream: answer one `ReplSync`
    /// pull. `segment` 0 asks for a snapshot bootstrap; otherwise we
    /// ship the next run of CRC-framed WAL records past `(segment,
    /// offset)`, pinning checkpoint retention at the position the
    /// replica will resume from. A position we can no longer serve (the
    /// segment was retired past the lag cap, or never existed) heals in
    /// the same round trip by answering with a bootstrap instead of an
    /// error.
    fn repl_sync(&self, name: &str, replica: &str, segment: u64, offset: u64) -> Response {
        let Some(c) = self.registry.get(name) else {
            return Response::Error {
                message: format!("unknown collection {name:?}"),
            };
        };
        let Some(d) = c.durability.clone() else {
            return Response::Error {
                message: format!(
                    "collection {name:?} has no WAL to replicate (serve the primary with \
                     --data-dir or --snapshot/--wal-dir)"
                ),
            };
        };
        if segment == 0 {
            return Self::repl_bootstrap(&c, &d, replica);
        }
        match d.read_chunk(segment, offset) {
            Ok(Some(chunk)) => {
                let (next_segment, next_offset) = if chunk.end_of_segment {
                    (
                        segment + 1,
                        crate::coordinator::durability::wal::SEGMENT_HEADER,
                    )
                } else {
                    (segment, chunk.next_offset)
                };
                d.repl_note(replica, next_segment);
                Response::ReplRecords {
                    segment,
                    next_segment,
                    next_offset,
                    behind_bytes: d.repl_backlog(next_segment, next_offset),
                    primary_records: d.wal_records(),
                    bytes: chunk.bytes,
                }
            }
            Ok(None) => Self::repl_bootstrap(&c, &d, replica),
            Err(e) => Response::Error {
                message: format!("replication read failed: {e}"),
            },
        }
    }

    /// Serve a snapshot bootstrap: checkpoint (so the image is current
    /// and the WAL just rotated), pin retention at the new active
    /// segment, and ship the image bytes with the resume position.
    fn repl_bootstrap(
        c: &Arc<Collection>,
        d: &Arc<crate::coordinator::durability::Durability>,
        replica: &str,
    ) -> Response {
        if let Err(e) = c.checkpoint() {
            return Response::Error {
                message: format!("bootstrap checkpoint failed: {e}"),
            };
        }
        let segment = d.active_seq();
        d.repl_note(replica, segment);
        let snapshot = match std::fs::read(d.snapshot_path()) {
            Ok(b) => b,
            Err(e) => {
                return Response::Error {
                    message: format!("bootstrap snapshot read failed: {e}"),
                }
            }
        };
        // The image must fit one response frame (with headroom for the
        // fixed fields). Past that, this pairing needs a sharded
        // bootstrap — punt explicitly rather than ship a frame the
        // replica will reject.
        if snapshot.len() as u64 + 1024 > u64::from(protocol::MAX_FRAME) {
            return Response::Error {
                message: format!(
                    "snapshot too large to bootstrap over the wire ({} bytes > {} frame cap)",
                    snapshot.len(),
                    protocol::MAX_FRAME
                ),
            };
        }
        Response::ReplBootstrap {
            segment,
            offset: crate::coordinator::durability::wal::SEGMENT_HEADER,
            primary_records: d.wal_records(),
            snapshot,
        }
    }

    /// Aggregate stats across the registry: arena and WAL counters are
    /// summed over collections; the kernel label is `default`'s (every
    /// collection picks its own tier by bit width). With `detail`
    /// (`StatsDetailed`), the per-collection section rides after the
    /// aggregates, sorted by name like `ListCollections`, then the
    /// per-request latency section; without `detail` the response is
    /// byte-identical to the pre-breakdown format. Detailed answers
    /// need a client as new as the server (see
    /// [`Request::StatsDetailed`] for the compatibility contract).
    fn stats(&self, detail: bool) -> Response {
        let mut st = self.metrics.snapshot();
        let collections = self.registry.list();
        st.collections = collections.len() as u64;
        for c in &collections {
            if let Some(arena) = c.store.arena() {
                st.pending_rows += arena.pending_rows() as u64;
                st.drains += arena.drains();
                st.tombstones += arena.tombstones() as u64;
            }
            if let Some(d) = &c.durability {
                st.wal_records += d.wal_records();
                st.wal_bytes += d.wal_bytes();
                st.last_checkpoint_rows += d.last_checkpoint_rows();
            }
            if detail {
                st.per_collection.push(c.stats());
            }
        }
        if detail {
            st.per_request = self.metrics.per_request();
            // Only replicas carry the replication tail (see the
            // StatsSnapshot encoding contract).
            if let Some(r) = &self.replica {
                st.replication = Some(r.stats());
            }
            // The reactor/batcher section rides in both serve modes:
            // thread mode reports zero reactor counters but a live
            // batcher queue depth.
            st.reactor = Some(self.metrics.reactor_stats());
        }
        if let Some(arena) = self.default.store.arena() {
            st.kernel = arena.kernel_kind().label().to_string();
        }
        Response::Stats(st)
    }
}

/// Decrements the connection gauge when a connection thread exits (or
/// when spawning it fails).
struct ConnTicket(Arc<Metrics>);

impl Drop for ConnTicket {
    fn drop(&mut self) {
        self.0.connections.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Run the server until the listener errors. Binds, then reports the
/// bound address through `ready` (useful for ephemeral-port tests).
pub fn serve(
    projector: Arc<Projector>,
    cfg: ServerConfig,
    ready: Option<std::sync::mpsc::Sender<std::net::SocketAddr>>,
) -> crate::Result<()> {
    // Multi-reactor mode binds N SO_REUSEPORT listeners on the same
    // address so the kernel spreads connections across the per-thread
    // event loops; every other mode binds exactly one normal listener.
    let multi = cfg.server_mode == ServerMode::Reactor && cfg.reactor_threads > 0;
    let listeners = if multi {
        crate::coordinator::reactor::bind_reuseport_group(&cfg.addr, cfg.reactor_threads)?
    } else {
        vec![TcpListener::bind(&cfg.addr)?]
    };
    let addr = listeners[0].local_addr()?;
    if let Some(tx) = ready {
        let _ = tx.send(addr);
    }
    // Sets the process-global log threshold (no-op when neither the
    // flag nor CRP_LOG is set) — concurrent servers share it.
    obs::log::init_from_env(cfg.log_level.as_deref())?;
    let state = ServiceState::open(projector, &cfg)?;
    if cfg.durability.is_some() || cfg.data_dir.is_some() {
        obs::log::info(
            "crp::server",
            "durability on",
            &[
                ("collections", state.registry.len().to_string()),
                (
                    "recovered_sketches",
                    state
                        .registry
                        .list()
                        .iter()
                        .map(|c| c.store.len())
                        .sum::<usize>()
                        .to_string(),
                ),
            ],
        );
    }
    // The /metrics listener holds its own render closure over the
    // shared state; dropping it (server exit) stops the thread.
    let _metrics_endpoint = match &cfg.metrics_addr {
        Some(addr) => {
            let render_state = state.clone();
            let health_state = state.clone();
            let ep = obs::http::MetricsEndpoint::spawn(
                addr,
                Arc::new(move || {
                    obs::expo::render(
                        &render_state.metrics,
                        &render_state.registry,
                        render_state.replica.as_deref(),
                    )
                }),
                Arc::new(move || health_state.health()),
            )?;
            obs::log::info(
                "crp::server",
                "metrics endpoint up",
                &[("addr", ep.addr().to_string())],
            );
            Some(ep)
        }
        None => None,
    };
    if cfg.server_mode == ServerMode::Reactor {
        // The reactor owns the listeners from here; it shares the
        // router, metrics endpoint, and shutdown story with thread
        // mode and differs only in connection scheduling.
        return crate::coordinator::reactor::serve_reactor(
            listeners,
            state,
            crate::coordinator::reactor::ReactorOptions {
                max_conns: cfg.max_conns,
                workers: cfg.reactor_workers,
                conn_timeout: cfg.conn_timeout,
                shutdown: cfg.shutdown.clone(),
            },
        );
    }
    let listener = listeners.into_iter().next().expect("one listener bound");
    for stream in listener.incoming() {
        let stream = stream?;
        if cfg.max_conns > 0
            && state.metrics.connections.load(Ordering::Relaxed) >= cfg.max_conns as u64
        {
            // One clean Error frame, then close — the client sees why
            // instead of a silent reset.
            let _ = reject_connection(stream, cfg.max_conns);
            continue;
        }
        state.metrics.connections.fetch_add(1, Ordering::Relaxed);
        let ticket = ConnTicket(state.metrics.clone());
        let state = state.clone();
        std::thread::Builder::new()
            .name("crp-conn".into())
            .spawn(move || {
                let _ticket = ticket;
                let _ = handle_connection(stream, state);
            })?;
    }
    Ok(())
}

pub(crate) fn reject_connection(stream: TcpStream, max_conns: usize) -> crate::Result<()> {
    let mut writer = std::io::BufWriter::new(stream);
    let resp = Response::Error {
        message: format!("connection limit reached ({max_conns}); retry later"),
    };
    protocol::write_frame(&mut writer, &resp.encode())
}

fn handle_connection(stream: TcpStream, state: Arc<ServiceState>) -> crate::Result<()> {
    stream.set_nodelay(true)?;
    // Socket hardening: a stalled or idle peer past the timeout fails
    // its next read/write and the connection closes through the normal
    // debug-logged path below — never a warn, never a stuck thread.
    if let Some(t) = state.conn_timeout {
        stream.set_read_timeout(Some(t))?;
        stream.set_write_timeout(Some(t))?;
    }
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    // Frame and response buffers live for the whole connection: steady
    // state reads and writes allocate nothing once both have grown to
    // the connection's largest frame.
    let mut frame = Vec::new();
    let mut out = Vec::new();
    loop {
        if let Err(e) = protocol::read_frame_into(&mut reader, &mut frame) {
            // A closed peer is the normal end of every connection,
            // not an incident — debug, never warn.
            obs::log::debug(
                "crp::server",
                "connection closed",
                &[("peer", peer.clone()), ("reason", e.to_string())],
            );
            return Ok(());
        }
        // Full-path timing starts once a frame is in hand: decode →
        // route/handle → encode+write, the whole server-side latency a
        // client observes past its own socket.
        let t0 = Instant::now();
        let decoded = Request::decode(&frame);
        let decode_us = t0.elapsed().as_micros() as u64;
        let h0 = Instant::now();
        let (resp, meta) = match decoded {
            Ok(req) => state.handle_traced(req),
            Err(e) => (
                Response::Error {
                    message: format!("bad request: {e}"),
                },
                obs::ReqMeta {
                    kind: obs::RequestKind::Admin,
                    collection: None,
                    candidates: None,
                },
            ),
        };
        let handle_us = h0.elapsed().as_micros() as u64;
        let w0 = Instant::now();
        out.clear();
        resp.encode_into(&mut out);
        protocol::write_frame(&mut writer, &out)?;
        let write_us = w0.elapsed().as_micros() as u64;
        let total_us = (decode_us + handle_us + write_us).max(1);
        observe_request(&state, &meta, total_us, decode_us, handle_us, write_us);
    }
}

/// Per-request accounting shared by both front-ends: the full-path
/// latency histogram, then exactly one log line per request — a
/// slow-query warning when the threshold fires, else a sampled debug
/// trace.
pub(crate) fn observe_request(
    state: &ServiceState,
    meta: &obs::ReqMeta,
    total_us: u64,
    decode_us: u64,
    handle_us: u64,
    write_us: u64,
) {
    state.metrics.requests.hist(meta.kind).record(total_us);
    if state.obs.slow_query_us > 0 && total_us >= state.obs.slow_query_us {
        state.metrics.slow_queries.fetch_add(1, Ordering::Relaxed);
        // Retained in the ring too, so `crp slow` can fetch the
        // recent offenders after the stderr lines scroll away.
        state.slow_ring.push(
            meta.kind,
            meta.collection.as_deref().unwrap_or(DEFAULT_COLLECTION),
            total_us,
            meta.candidates.unwrap_or(0),
        );
        let mut fields = obs::stage_fields(meta, total_us, decode_us, handle_us, write_us);
        // The kernel tier is resolved lazily — only slow queries
        // pay the registry lookup.
        let name = meta.collection.as_deref().unwrap_or(DEFAULT_COLLECTION);
        if let Some(c) = state.registry.get(name) {
            if let Some(arena) = c.store.arena() {
                fields.push(("kernel", arena.kernel_kind().label().to_string()));
            }
        }
        obs::log::warn("crp::slow_query", "slow request", &fields);
    } else if state.obs.should_trace() {
        obs::log::debug(
            "crp::trace",
            "request",
            &obs::stage_fields(meta, total_us, decode_us, handle_us, write_us),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::ProjectionConfig;

    fn state(k: usize) -> Arc<ServiceState> {
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k,
            seed: 7,
            ..Default::default()
        }));
        ServiceState::new(projector, &ServerConfig::default())
    }

    #[test]
    fn register_then_estimate() {
        let s = state(512);
        let (u, v) = crate::data::pairs::unit_pair_with_rho(128, 0.85, 3);
        let r1 = s.handle(Request::Register {
            id: "u".into(),
            vector: u,
        });
        assert!(matches!(r1, Response::Registered { .. }));
        let r2 = s.handle(Request::Register {
            id: "v".into(),
            vector: v,
        });
        assert!(matches!(r2, Response::Registered { .. }));
        match s.handle(Request::Estimate {
            a: "u".into(),
            b: "v".into(),
        }) {
            Response::Estimate { rho, std_err, .. } => {
                assert!(
                    (rho - 0.85).abs() < 4.0 * std_err + 0.05,
                    "rho {rho} err {std_err}"
                );
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn unknown_id_errors() {
        let s = state(64);
        match s.handle(Request::Estimate {
            a: "nope".into(),
            b: "nada".into(),
        }) {
            Response::Error { message } => assert!(message.contains("nope")),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn knn_orders_by_similarity() {
        let s = state(512);
        let (base, near) = crate::data::pairs::unit_pair_with_rho(96, 0.95, 11);
        let (_, far) = crate::data::pairs::unit_pair_with_rho(96, 0.1, 12);
        s.handle(Request::Register {
            id: "near".into(),
            vector: near,
        });
        s.handle(Request::Register {
            id: "far".into(),
            vector: far,
        });
        match s.handle(Request::Knn {
            vector: base,
            n: 2,
        }) {
            Response::Knn { hits } => {
                assert_eq!(hits.len(), 2);
                assert_eq!(hits[0].id, "near");
                assert!(hits[0].rho > hits[1].rho);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn knn_scan_is_byte_identical_to_bruteforce() {
        let s = state(256);
        let mut g = crate::mathx::Pcg64::new(77, 0);
        for i in 0..60 {
            let v: Vec<f32> = (0..48).map(|_| g.next_f64() as f32 - 0.5).collect();
            s.handle(Request::Register {
                id: format!("v{i:02}"),
                vector: v,
            });
        }
        let q: Vec<f32> = (0..48).map(|_| g.next_f64() as f32 - 0.5).collect();
        // Register the query too: the batcher is deterministic, so its
        // stored sketch equals the sketch Knn computes internally.
        s.handle(Request::Register {
            id: "query".into(),
            vector: q.clone(),
        });
        let qs = s.store.get("query").unwrap();
        let mut want: Vec<(String, usize)> = Vec::new();
        s.store.for_each(|id, codes| {
            want.push((
                id.to_string(),
                crate::coding::collision_count_packed(&qs, codes),
            ));
        });
        want.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        want.truncate(10);
        match s.handle(Request::Knn { vector: q, n: 10 }) {
            Response::Knn { hits } => {
                assert_eq!(hits.len(), 10);
                assert_eq!(hits[0].id, "query");
                for (hit, (id, c)) in hits.iter().zip(&want) {
                    assert_eq!(&hit.id, id);
                    assert_eq!(hit.rho, s.estimator.estimate_from_count(*c, s.k));
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn topk_batch_matches_per_query_knn() {
        let s = state(128);
        let mut g = crate::mathx::Pcg64::new(5, 5);
        for i in 0..40 {
            let v: Vec<f32> = (0..32).map(|_| g.next_f64() as f32 - 0.5).collect();
            s.handle(Request::Register {
                id: format!("c{i}"),
                vector: v,
            });
        }
        let queries: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..32).map(|_| g.next_f64() as f32 - 0.5).collect())
            .collect();
        let batched = match s.handle(Request::TopK {
            vectors: queries.clone(),
            n: 3,
        }) {
            Response::TopK { results } => results,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(batched.len(), queries.len());
        for (q, want) in queries.into_iter().zip(&batched) {
            match s.handle(Request::Knn { vector: q, n: 3 }) {
                Response::Knn { hits } => assert_eq!(&hits, want),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn register_batch_matches_per_vector_register() {
        let s = state(256);
        let mut g = crate::mathx::Pcg64::new(31, 0);
        let vectors: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..40).map(|_| g.next_f64() as f32 - 0.5).collect())
            .collect();
        for (i, v) in vectors.iter().enumerate() {
            s.handle(Request::Register {
                id: format!("single{i}"),
                vector: v.clone(),
            });
        }
        let ids: Vec<String> = (0..20).map(|i| format!("bulk{i}")).collect();
        match s.handle(Request::RegisterBatch {
            ids: ids.clone(),
            vectors: vectors.clone(),
        }) {
            Response::RegisteredBatch { count } => assert_eq!(count, 20),
            other => panic!("unexpected {other:?}"),
        }
        // The fused pipeline must produce byte-identical sketches.
        for i in 0..20 {
            assert_eq!(
                s.store.get(&format!("bulk{i}")),
                s.store.get(&format!("single{i}")),
                "vector {i}"
            );
        }
        match s.handle(Request::RegisterBatch {
            ids,
            vectors: vec![],
        }) {
            Response::Error { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        match s.handle(Request::Stats) {
            Response::Stats(st) => {
                assert_eq!(st.registered, 40);
                assert_eq!(st.collections, 1);
                assert!(!st.kernel.is_empty(), "stats must name the scan kernel");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn register_sparse_matches_per_vector_dense_register() {
        use crate::data::sparse::CsrMatrix;

        let s = state(256);
        let d = 300usize;
        let mut g = crate::mathx::Pcg64::new(41, 0);
        let mut csr = CsrMatrix::with_capacity(12, 0, d);
        for row in 0..12usize {
            let nnz = row % 5; // includes empty rows
            let mut cols: Vec<u32> = Vec::new();
            while cols.len() < nnz {
                let c = g.next_below(d as u64) as u32;
                if !cols.contains(&c) {
                    cols.push(c);
                }
            }
            cols.sort_unstable();
            let vals: Vec<f32> = cols
                .iter()
                .map(|_| (g.next_f64() as f32 - 0.5) * 4.0)
                .collect();
            csr.push_row(&cols, &vals);
        }
        for r in 0..csr.rows() {
            s.handle(Request::Register {
                id: format!("dense{r}"),
                vector: csr.row_dense(r),
            });
        }
        let ids: Vec<String> = (0..csr.rows()).map(|r| format!("sparse{r}")).collect();
        let (resp, meta) = s.handle_traced(Request::RegisterSparse {
            ids: ids.clone(),
            csr: csr.clone(),
        });
        match resp {
            Response::RegisteredBatch { count } => assert_eq!(count, 12),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(meta.kind, obs::RequestKind::RegisterSparse);
        assert_eq!(meta.candidates, Some(csr.nnz() as u64));
        // The O(nnz) gather path must produce byte-identical sketches.
        for r in 0..csr.rows() {
            assert_eq!(
                s.store.get(&format!("sparse{r}")),
                s.store.get(&format!("dense{r}")),
                "row {r}"
            );
        }
        // Mismatched id/row counts are a clean error, not a panic.
        match s.handle(Request::RegisterSparse {
            ids: vec!["one".into()],
            csr,
        }) {
            Response::Error { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        // Scoped routing errors cleanly on unknown collections too.
        match s.handle(Request::Scoped {
            collection: "ghost".into(),
            inner: Box::new(Request::RegisterSparse {
                ids: vec![],
                csr: CsrMatrix::with_capacity(0, 0, 4),
            }),
        }) {
            Response::Error { message } => assert!(message.contains("ghost"), "{message}"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn approx_topk_routes_and_falls_back_to_exact_on_small_stores() {
        let s = state(128);
        let mut g = crate::mathx::Pcg64::new(3, 3);
        for i in 0..50 {
            let v: Vec<f32> = (0..24).map(|_| g.next_f64() as f32 - 0.5).collect();
            s.handle(Request::Register {
                id: format!("a{i:02}"),
                vector: v,
            });
        }
        let queries: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..24).map(|_| g.next_f64() as f32 - 0.5).collect())
            .collect();
        // Below the approx floor the index path falls back to the exact
        // sweep, so ApproxTopK ≡ TopK byte-identically here.
        let exact = s.handle(Request::TopK {
            vectors: queries.clone(),
            n: 5,
        });
        let approx = s.handle(Request::ApproxTopK {
            vectors: queries,
            n: 5,
            probes: 0,
        });
        assert_eq!(exact, approx);
        // Unknown collections error cleanly on the approx path too.
        match s.handle(Request::Scoped {
            collection: "ghost".into(),
            inner: Box::new(Request::ApproxTopK {
                vectors: vec![vec![1.0; 8]],
                n: 1,
                probes: 2,
            }),
        }) {
            Response::Error { message } => assert!(message.contains("ghost"), "{message}"),
            other => panic!("unexpected {other:?}"),
        }
        // The detailed stats breakdown names every collection with its
        // gauges; the plain Stats answer stays aggregates-only.
        match s.handle(Request::StatsDetailed) {
            Response::Stats(st) => {
                assert_eq!(st.per_collection.len(), 1);
                assert_eq!(st.per_collection[0].name, "default");
                assert_eq!(st.per_collection[0].rows, 50);
                assert_eq!(st.per_collection[0].wal_bytes, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        match s.handle(Request::Stats) {
            Response::Stats(st) => assert!(st.per_collection.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn stats_track_activity() {
        let s = state(64);
        s.handle(Request::Register {
            id: "a".into(),
            vector: vec![1.0; 32],
        });
        match s.handle(Request::Stats) {
            Response::Stats(st) => {
                assert_eq!(st.registered, 1);
                assert!(st.vectors_projected >= 1);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn scoped_requests_route_to_their_collection() {
        let s = state(128);
        // Scoped to default ≡ unscoped.
        let r = s.handle(Request::Scoped {
            collection: "default".into(),
            inner: Box::new(Request::Register {
                id: "x".into(),
                vector: vec![1.0; 16],
            }),
        });
        assert!(matches!(r, Response::Registered { .. }), "{r:?}");
        assert!(s.store.get("x").is_some());
        // Unknown collections are a clean error on every data path.
        for inner in [
            Request::Register {
                id: "y".into(),
                vector: vec![1.0; 4],
            },
            Request::Knn {
                vector: vec![1.0; 4],
                n: 1,
            },
            Request::Persist,
        ] {
            match s.handle(Request::Scoped {
                collection: "ghost".into(),
                inner: Box::new(inner),
            }) {
                Response::Error { message } => {
                    assert!(message.contains("ghost"), "{message}")
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // Admin requests are not collection-scoped... but scoping Ping
        // is harmless; scoping ListCollections still answers.
        match s.handle(Request::Scoped {
            collection: "default".into(),
            inner: Box::new(Request::ListCollections),
        }) {
            Response::Collections { collections } => {
                assert_eq!(collections.len(), 1);
                assert_eq!(collections[0].name, "default");
                assert_eq!(collections[0].bits, 2);
                assert!(!collections[0].durable);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn handle_traced_reports_kind_collection_and_candidates() {
        let s = state(128);
        let (resp, meta) = s.handle_traced(Request::Register {
            id: "a".into(),
            vector: vec![1.0; 16],
        });
        assert!(matches!(resp, Response::Registered { .. }));
        assert_eq!(meta.kind, obs::RequestKind::Register);
        assert_eq!(meta.collection, None);
        assert_eq!(meta.candidates, None);

        // Scoped requests surface their collection; ApproxTopK reports
        // its candidate count (0 here: small store → exact fallback).
        let (resp, meta) = s.handle_traced(Request::Scoped {
            collection: "default".into(),
            inner: Box::new(Request::ApproxTopK {
                vectors: vec![vec![0.5; 16]],
                n: 1,
                probes: 0,
            }),
        });
        assert!(matches!(resp, Response::TopK { .. }), "{resp:?}");
        assert_eq!(meta.kind, obs::RequestKind::ApproxTopK);
        assert_eq!(meta.collection.as_deref(), Some("default"));
        assert_eq!(meta.candidates, Some(0));

        // Unknown-collection errors still classify (no candidates).
        let (resp, meta) = s.handle_traced(Request::Scoped {
            collection: "ghost".into(),
            inner: Box::new(Request::Knn {
                vector: vec![1.0; 8],
                n: 1,
            }),
        });
        assert!(matches!(resp, Response::Error { .. }));
        assert_eq!(meta.kind, obs::RequestKind::Knn);
        assert_eq!(meta.collection.as_deref(), Some("ghost"));
    }

    #[test]
    fn metrics_text_renders_exposition_over_the_protocol() {
        let s = state(64);
        s.handle(Request::Register {
            id: "a".into(),
            vector: vec![1.0; 16],
        });
        match s.handle(Request::MetricsText) {
            Response::MetricsText { text } => {
                assert!(text.contains("# TYPE crp_registered_total counter"), "{text}");
                assert!(text.contains("crp_registered_total 1"));
                assert!(text.contains("crp_collection_rows{collection=\"default\"} 1"));
                assert!(text.contains("# TYPE crp_request_duration_us histogram"));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn stats_detailed_carries_per_request_rows() {
        let s = state(64);
        // The connection loop records these; simulate two requests.
        s.metrics
            .requests
            .hist(obs::RequestKind::Knn)
            .record(1_000);
        s.metrics.requests.hist(obs::RequestKind::Knn).record(3_000);
        match s.handle(Request::StatsDetailed) {
            Response::Stats(st) => {
                assert_eq!(st.per_request.len(), 1);
                assert_eq!(st.per_request[0].kind, "knn");
                assert_eq!(st.per_request[0].count, 2);
                assert!(st.per_request[0].p99_us >= 2_048);
            }
            other => panic!("unexpected {other:?}"),
        }
        // The plain Stats answer stays byte-compatible: no rows.
        match s.handle(Request::Stats) {
            Response::Stats(st) => assert!(st.per_request.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn replica_rejects_writes_until_promoted() {
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 64,
            seed: 7,
            ..Default::default()
        }));
        // Port 1 never answers: the applier just backs off in the
        // background while we exercise the router's replica posture.
        let cfg = ServerConfig {
            replicate_from: Some("127.0.0.1:1".into()),
            repl_backoff_min: std::time::Duration::from_millis(10),
            repl_backoff_max: std::time::Duration::from_millis(50),
            ..Default::default()
        };
        let s = ServiceState::new(projector.clone(), &cfg);

        // Every write is rejected with a redirect naming the primary.
        for write in [
            Request::Register {
                id: "a".into(),
                vector: vec![1.0; 16],
            },
            Request::Remove { id: "a".into() },
            Request::RegisterSparse {
                ids: vec!["s".into()],
                csr: {
                    let mut m = crate::data::sparse::CsrMatrix::with_capacity(1, 2, 16);
                    m.push_row(&[1, 5], &[1.0, -1.0]);
                    m
                },
            },
            Request::Persist,
            Request::DropCollection { name: "x".into() },
        ] {
            match s.handle(write) {
                Response::Error { message } => {
                    assert!(message.contains("127.0.0.1:1"), "{message}");
                    assert!(message.contains("promote"), "{message}");
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // Reads still answer.
        assert!(matches!(s.handle(Request::Ping), Response::Pong));
        match s.handle(Request::Knn {
            vector: vec![1.0; 16],
            n: 1,
        }) {
            Response::Knn { hits } => assert!(hits.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
        // Not ready before bootstrap; the detail names the lag.
        let (ready, detail) = s.health();
        assert!(!ready, "{detail}");
        // StatsDetailed carries the replication tail; plain Stats
        // stays byte-compatible without it.
        match s.handle(Request::StatsDetailed) {
            Response::Stats(st) => {
                let r = st.replication.expect("replica stats tail");
                assert!(r.active);
                assert_eq!(r.primary, "127.0.0.1:1");
            }
            other => panic!("unexpected {other:?}"),
        }
        match s.handle(Request::Stats) {
            Response::Stats(st) => assert!(st.replication.is_none()),
            other => panic!("unexpected {other:?}"),
        }

        // Promotion flips the posture: writes accepted, ready, and a
        // second promote is a clean no-op.
        match s.handle(Request::Promote) {
            Response::Promoted { was_replica } => assert!(was_replica),
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(
            s.handle(Request::Register {
                id: "a".into(),
                vector: vec![1.0; 16],
            }),
            Response::Registered { .. }
        ));
        assert!(s.health().0);
        match s.handle(Request::Promote) {
            Response::Promoted { was_replica } => assert!(!was_replica),
            other => panic!("unexpected {other:?}"),
        }

        // A server that never replicated answers Promote too (no-op).
        let primary = state(64);
        match primary.handle(Request::Promote) {
            Response::Promoted { was_replica } => assert!(!was_replica),
            other => panic!("unexpected {other:?}"),
        }

        // Replication and local durability are mutually exclusive.
        let dir = std::env::temp_dir().join(format!("crp-repl-excl-{}", std::process::id()));
        let bad = ServerConfig {
            replicate_from: Some("127.0.0.1:1".into()),
            data_dir: Some(dir),
            ..Default::default()
        };
        assert!(ServiceState::open(projector, &bad).is_err());
    }

    #[test]
    fn slow_queries_are_served_from_the_ring() {
        let s = state(64);
        match s.handle(Request::SlowQueries { max: 0 }) {
            Response::SlowQueries { entries } => assert!(entries.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
        s.slow_ring.push(obs::RequestKind::Knn, "default", 12_345, 7);
        s.slow_ring.push(obs::RequestKind::ApproxTopK, "web", 99_000, 1_000);
        match s.handle(Request::SlowQueries { max: 1 }) {
            Response::SlowQueries { entries } => {
                assert_eq!(entries.len(), 1);
                assert_eq!(entries[0].kind, "approx_topk");
                assert_eq!(entries[0].collection, "web");
                assert_eq!(entries[0].total_us, 99_000);
                assert_eq!(entries[0].candidates, 1_000);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn create_collection_validates_bits_cross_check() {
        let s = state(64);
        match s.handle(Request::CreateCollection {
            name: "u4".into(),
            scheme: crate::coding::Scheme::Uniform,
            w: 1.0,
            bits: 2, // h_w at w=1 packs 4 bits, not 2
            k: 32,
            seed: 1,
            checkpoint_every: 0,
            kind: crate::projection::MatrixKind::Gaussian,
        }) {
            Response::Error { message } => {
                assert!(message.contains("4 bit"), "{message}")
            }
            other => panic!("unexpected {other:?}"),
        }
        match s.handle(Request::CreateCollection {
            name: "u4".into(),
            scheme: crate::coding::Scheme::Uniform,
            w: 1.0,
            bits: 0, // 0 = derive
            k: 32,
            seed: 1,
            checkpoint_every: 0,
            kind: crate::projection::MatrixKind::Gaussian,
        }) {
            Response::CollectionCreated { name } => assert_eq!(name, "u4"),
            other => panic!("unexpected {other:?}"),
        }
        match s.handle(Request::Stats) {
            Response::Stats(st) => assert_eq!(st.collections, 2),
            other => panic!("unexpected {other:?}"),
        }
        match s.handle(Request::DropCollection { name: "u4".into() }) {
            Response::CollectionDropped { existed } => assert!(existed),
            other => panic!("unexpected {other:?}"),
        }
        match s.handle(Request::DropCollection { name: "u4".into() }) {
            Response::CollectionDropped { existed } => assert!(!existed),
            other => panic!("unexpected {other:?}"),
        }
        match s.handle(Request::DropCollection {
            name: "default".into(),
        }) {
            Response::Error { message } => assert!(message.contains("default"), "{message}"),
            other => panic!("unexpected {other:?}"),
        }
    }
}
