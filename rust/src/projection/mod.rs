//! Random projection engine.
//!
//! Implements Eq. (1) of the paper: `x = u × R`, `R ∈ R^{D×k}`,
//! `r_ij ~ N(0,1)` i.i.d. The projection matrix is never materialized
//! whole — [`matrix::RowMatrix`] regenerates any row of `R`
//! deterministically from `(seed, row)`, so the same logical `R` is
//! shared by the pure-Rust path, the PJRT-artifact path, sparse and
//! dense inputs, and test oracles, for any `D`.
//!
//! * [`matrix`] — seeded row-wise generation of `R`, tile assembly.
//! * [`gemm`] — cache-blocked dense `U[B,D] · R[D,k]` (pure Rust).
//! * [`sparse`] — O(nnz) kernels: the gather kernel (bit-identical to
//!   the dense GEMM on densified input) and the opt-in very-sparse ±1
//!   matrix ([`MatrixKind::SignSparse`], add/sub only).
//! * [`engine`] — the [`Projector`]: dense/sparse/batched projection,
//!   optionally dispatching D-tiles to the AOT PJRT artifact.

pub mod matrix;
pub mod gemm;
pub mod sparse;
pub mod engine;

pub use engine::{Backend, ProjectionConfig, Projector};
pub use matrix::RowMatrix;
pub use sparse::MatrixKind;
