//! Sparse projection kernels: O(nnz) per row instead of O(d).
//!
//! Two engines live here:
//!
//! * **Gather kernel** ([`project_csr_row_into`]) — projects one CSR row
//!   against the standard Gaussian [`RowMatrix`] by generating only the
//!   rows of `R` its nonzeros touch. The accumulation replays the dense
//!   GEMM's operation sequence *exactly* (same quad grouping, same
//!   skip condition, same [`axpy4`]/[`axpy`] bodies, same order), so the
//!   packed codes downstream are byte-identical to the dense path on
//!   the densified vector — pinned by tests here and in
//!   `tests/proptests.rs`.
//! * **Sign-sparse kernel** ([`accumulate_sign_row`]) — an opt-in
//!   very-sparse ±1 matrix (`MatrixKind::SignSparse { s }`, entries
//!   +1/−1 each with probability `1/(2s)`, else 0 — arXiv 2006.16180 /
//!   the classic very-sparse-projection trick) where every accumulation
//!   is an add or subtract, no multiplies. Dense and sparse inputs on a
//!   sign-sparse collection run the *same* per-nonzero kernel, so the
//!   two ingest paths stay bit-identical to each other.
//!
//! ## Why the gather kernel is bit-exact
//!
//! The dense path pads each row to `d_tile` and hands tiles to
//! `gemm_acc`, which walks the contraction in quads of four aligned
//! columns (skipping all-zero quads) and finishes each tile with
//! single-column tails when `d_tile % 4 != 0`. Quads never straddle the
//! 64-wide cache blocks (64 % 4 == 0), so a local column `li` belongs
//! to a quad iff `(li / 4) * 4 + 4 <= d_tile`. Columns absent from the
//! CSR row are zeros in the dense padded buffer; quads containing no
//! nonzero are skipped by the all-zero test on both paths, which also
//! makes the result independent of the batch's padded width. f32
//! addition is deterministic, so replaying the identical operation
//! sequence on identical operands reproduces identical bits.

use super::gemm::{axpy, axpy4};
use super::matrix::RowMatrix;
use crate::mathx::Pcg64;

/// Which projection matrix a collection draws its rows from.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MatrixKind {
    /// Dense Gaussian `r_ij ~ N(0,1)` (the paper's Eq. (1); default).
    #[default]
    Gaussian,
    /// Very sparse ±1 matrix: `r_ij ∈ {+1, 0, −1}` with
    /// `P(±1) = 1/(2s)`, so each column touches ~`k/s` accumulators and
    /// every touch is an add/sub. Trades estimator variance for ingest
    /// speed on sparse corpora.
    SignSparse { s: u32 },
}

impl MatrixKind {
    /// Wire/manifest discriminant.
    pub fn code(self) -> u8 {
        match self {
            MatrixKind::Gaussian => 0,
            MatrixKind::SignSparse { .. } => 1,
        }
    }

    /// Wire/manifest parameter (`s`; 0 for Gaussian).
    pub fn param(self) -> u32 {
        match self {
            MatrixKind::Gaussian => 0,
            MatrixKind::SignSparse { s } => s,
        }
    }

    /// Inverse of [`MatrixKind::code`]/[`MatrixKind::param`].
    pub fn from_wire(code: u8, param: u32) -> crate::Result<MatrixKind> {
        match code {
            0 => Ok(MatrixKind::Gaussian),
            1 => {
                anyhow::ensure!(param >= 1, "sign-sparse s must be >= 1, got {param}");
                Ok(MatrixKind::SignSparse { s: param })
            }
            other => anyhow::bail!("unknown matrix kind {other}"),
        }
    }
}

impl std::fmt::Display for MatrixKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MatrixKind::Gaussian => write!(f, "gaussian"),
            MatrixKind::SignSparse { s } => write!(f, "sign-sparse(s={s})"),
        }
    }
}

/// Stream-id offset separating sign-row streams from the Gaussian
/// R-row streams (`0x52…`) and every other user of a collection seed.
const SIGN_STREAM_BASE: u64 = 0x53_0000_0000; // 'S'

/// `acc += v · sign_row(seed, s, row)` — the sign-sparse accumulation:
/// one uniform draw per coordinate, an add or a subtract where the draw
/// lands in the ±1 mass, no multiplies. Both the dense and the CSR
/// ingest paths call this per nonzero in ascending column order, so
/// they produce bit-identical projections.
pub fn accumulate_sign_row(seed: u64, s: u32, row: usize, v: f32, acc: &mut [f32]) {
    let mut g = Pcg64::new(seed, SIGN_STREAM_BASE + row as u64);
    let half = 1.0 / (2.0 * s as f64);
    let full = 2.0 * half;
    for a in acc.iter_mut() {
        let u = g.next_f64();
        if u < half {
            *a += v;
        } else if u < full {
            *a -= v;
        }
    }
}

/// Materialize sign row `row` as ±1/0 f32s (tests and oracles only —
/// the hot path never builds it).
pub fn sign_row(seed: u64, s: u32, row: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k];
    accumulate_sign_row(seed, s, row, 1.0, &mut out);
    out
}

/// Project one CSR row (`idx` strictly increasing, parallel `val`)
/// against the Gaussian `matrix`, accumulating into `acc` (length `k`,
/// caller-zeroed), touching only the `R` rows the nonzeros name.
///
/// `scratch` holds the up-to-four gathered `R` rows (resized to `4·k`);
/// reuse it across calls to stay allocation-free per row. `d_tile` must
/// be the projector's configured tile width — the quad/tail split
/// inside each tile depends on it (see the module docs).
pub fn project_csr_row_into(
    matrix: &RowMatrix,
    d_tile: usize,
    idx: &[u32],
    val: &[f32],
    scratch: &mut Vec<f32>,
    acc: &mut [f32],
) {
    let k = matrix.k;
    assert_eq!(acc.len(), k, "accumulator width mismatch");
    assert_eq!(idx.len(), val.len());
    debug_assert!(
        idx.windows(2).all(|w| w[0] < w[1]),
        "CSR row indices must be strictly increasing"
    );
    scratch.resize(4 * k, 0.0);
    let (r01, r23) = scratch.split_at_mut(2 * k);
    let (r0, r1) = r01.split_at_mut(k);
    let (r2, r3) = r23.split_at_mut(k);
    // Local columns below this form quads; the rest are tile tails.
    let quad_end = d_tile / 4 * 4;
    let n = idx.len();
    let mut p = 0usize;
    while p < n {
        // One tile's run of nonzeros: [p, tile_hi).
        let tile = idx[p] as usize / d_tile;
        let base = tile * d_tile;
        let mut tile_hi = p;
        while tile_hi < n && (idx[tile_hi] as usize) < base + d_tile {
            tile_hi += 1;
        }
        // Quads, ascending — exactly the dense kernel's traversal.
        let mut i = p;
        while i < tile_hi && (idx[i] as usize) < base + quad_end {
            let col0 = base + (idx[i] as usize - base) / 4 * 4;
            let mut a = [0.0f32; 4];
            while i < tile_hi && (idx[i] as usize) < col0 + 4 {
                a[idx[i] as usize - col0] = val[i];
                i += 1;
            }
            // Same skip the dense path applies to all-zero quads
            // (explicit zeros stored in the CSR hit it too).
            if a[0] != 0.0 || a[1] != 0.0 || a[2] != 0.0 || a[3] != 0.0 {
                matrix.fill_row(col0, r0);
                matrix.fill_row(col0 + 1, r1);
                matrix.fill_row(col0 + 2, r2);
                matrix.fill_row(col0 + 3, r3);
                axpy4(a[0], r0, a[1], r1, a[2], r2, a[3], r3, acc);
            }
        }
        // Tile-tail singles (only when d_tile % 4 != 0), ascending.
        while i < tile_hi {
            let v = val[i];
            if v != 0.0 {
                matrix.fill_row(idx[i] as usize, r0);
                axpy(v, r0, acc);
            }
            i += 1;
        }
        p = tile_hi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::{ProjectionConfig, Projector};

    fn sparse_row(seed: u64, d: usize, nnz: usize) -> (Vec<u32>, Vec<f32>) {
        let mut g = Pcg64::new(seed, 9);
        let mut cols: Vec<u32> = Vec::new();
        while cols.len() < nnz {
            let c = g.next_below(d as u64) as u32;
            if !cols.contains(&c) {
                cols.push(c);
            }
        }
        cols.sort_unstable();
        let vals = cols
            .iter()
            .map(|_| (g.next_f64() as f32 - 0.5) * 4.0)
            .collect();
        (cols, vals)
    }

    fn densify(idx: &[u32], val: &[f32], d: usize) -> Vec<f32> {
        let mut u = vec![0.0f32; d];
        for (&i, &v) in idx.iter().zip(val) {
            u[i as usize] = v;
        }
        u
    }

    #[test]
    fn gather_is_bit_identical_to_dense_gemm() {
        // Tile widths cover the quad-only case, dt % 4 != 0 tails, a
        // tile smaller than a quad, and multi-tile rows.
        for &(k, dt, d, nnz) in &[
            (16usize, 32usize, 200usize, 7usize),
            (24, 30, 200, 11),  // dt % 4 != 0: per-tile singles
            (8, 3, 50, 9),      // dt < 4: singles only
            (33, 64, 1000, 40), // many tiles, ragged k
            (16, 32, 64, 0),    // empty row
        ] {
            let p = Projector::new_cpu(ProjectionConfig {
                k,
                seed: 11,
                d_tile: dt,
                b_tile: 4,
                max_cached_tiles: 8,
                ..Default::default()
            });
            let (idx, val) = sparse_row(k as u64 ^ d as u64, d, nnz);
            let dense = p.project_batch(&densify(&idx, &val, d), 1, d.max(1));
            let mut acc = vec![0.0f32; k];
            let mut scratch = Vec::new();
            project_csr_row_into(p.matrix(), dt, &idx, &val, &mut scratch, &mut acc);
            assert_eq!(acc, dense, "k={k} dt={dt} d={d} nnz={nnz}");
        }
    }

    #[test]
    fn gather_independent_of_padded_width() {
        // The dense batch pads rows to the longest vector in the batch;
        // the gather result must match regardless of that width.
        let p = Projector::new_cpu(ProjectionConfig {
            k: 16,
            seed: 5,
            d_tile: 32,
            ..Default::default()
        });
        let (idx, val) = sparse_row(3, 100, 12);
        for &d in &[100usize, 128, 500] {
            let dense = p.project_batch(&densify(&idx, &val, d), 1, d);
            let mut acc = vec![0.0f32; 16];
            let mut scratch = Vec::new();
            project_csr_row_into(p.matrix(), 32, &idx, &val, &mut scratch, &mut acc);
            assert_eq!(acc, dense, "d={d}");
        }
    }

    #[test]
    fn explicit_zero_values_change_nothing() {
        let p = Projector::new_cpu(ProjectionConfig {
            k: 12,
            seed: 8,
            d_tile: 16,
            ..Default::default()
        });
        let idx = vec![1u32, 2, 17, 40];
        let val = vec![1.5f32, 0.0, -2.0, 0.0];
        let mut with_zeros = vec![0.0f32; 12];
        let mut scratch = Vec::new();
        project_csr_row_into(p.matrix(), 16, &idx, &val, &mut scratch, &mut with_zeros);
        let mut without = vec![0.0f32; 12];
        project_csr_row_into(p.matrix(), 16, &[1, 17], &[1.5, -2.0], &mut scratch, &mut without);
        assert_eq!(with_zeros, without);
    }

    #[test]
    fn sign_rows_deterministic_with_expected_density() {
        let (seed, s, k) = (7u64, 4u32, 4096usize);
        assert_eq!(sign_row(seed, s, 3, k), sign_row(seed, s, 3, k));
        assert_ne!(sign_row(seed, s, 3, k), sign_row(seed, s, 4, k));
        assert_ne!(sign_row(seed, s, 3, k), sign_row(seed + 1, s, 3, k));
        let row = sign_row(seed, s, 0, k);
        assert!(row.iter().all(|&v| v == 0.0 || v == 1.0 || v == -1.0));
        let nonzero = row.iter().filter(|&&v| v != 0.0).count() as f64 / k as f64;
        let want = 1.0 / s as f64;
        assert!((nonzero - want).abs() < 0.03, "density {nonzero} vs {want}");
    }

    #[test]
    fn sign_accumulate_matches_materialized_row() {
        let (seed, s, k) = (21u64, 8u32, 130usize);
        let row = sign_row(seed, s, 5, k);
        let mut acc = vec![0.5f32; k];
        accumulate_sign_row(seed, s, 5, -1.25, &mut acc);
        for (j, (&a, &r)) in acc.iter().zip(&row).enumerate() {
            assert_eq!(a, 0.5 + (-1.25) * r, "coord {j}");
        }
    }

    #[test]
    fn matrix_kind_wire_roundtrip() {
        for kind in [MatrixKind::Gaussian, MatrixKind::SignSparse { s: 3 }] {
            assert_eq!(MatrixKind::from_wire(kind.code(), kind.param()).unwrap(), kind);
        }
        assert!(MatrixKind::from_wire(2, 0).is_err());
        assert!(MatrixKind::from_wire(1, 0).is_err()); // s = 0 invalid
    }
}
