//! Deterministic, random-access generation of the Gaussian projection
//! matrix `R ∈ R^{D×k}`.
//!
//! Row `i` of `R` is produced by an independent PRNG stream keyed on
//! `(seed, i)`, so any row — and therefore any D-tile — can be
//! regenerated on demand without storing `R`. This is what lets the
//! engine stream tiles through the fixed-shape PJRT artifact and lets
//! the sparse path touch only the rows a vector actually uses.

use crate::mathx::NormalSampler;

/// Stream-id offset separating R-row streams from other users of the
/// same seed (offsets, datasets, ...).
const ROW_STREAM_BASE: u64 = 0x52_0000_0000; // 'R'

/// A virtual `D×k` Gaussian matrix with `r_ij ~ N(0,1)`, reproducible
/// row-by-row. `D` is unbounded — rows are generated as requested.
#[derive(Clone, Debug)]
pub struct RowMatrix {
    pub seed: u64,
    pub k: usize,
}

impl RowMatrix {
    pub fn new(seed: u64, k: usize) -> Self {
        assert!(k > 0);
        RowMatrix { seed, k }
    }

    /// Write row `i` (length `k`) into `out`.
    pub fn fill_row(&self, i: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.k);
        let mut ns = NormalSampler::new(self.seed, ROW_STREAM_BASE + i as u64);
        ns.fill_f32(out);
    }

    /// Materialize row `i`.
    pub fn row(&self, i: usize) -> Vec<f32> {
        let mut v = vec![0.0; self.k];
        self.fill_row(i, &mut v);
        v
    }

    /// Materialize the tile of rows `[row0, row0 + rows)` as a row-major
    /// `rows × k` buffer (zero-padded if the caller asks beyond a logical
    /// D — rows are always defined, so no padding is ever needed here;
    /// padding happens on the *data* side).
    pub fn fill_tile(&self, row0: usize, rows: usize, out: &mut [f32]) {
        assert_eq!(out.len(), rows * self.k);
        for r in 0..rows {
            self.fill_row(row0 + r, &mut out[r * self.k..(r + 1) * self.k]);
        }
    }

    /// Materialize a tile.
    pub fn tile(&self, row0: usize, rows: usize) -> Vec<f32> {
        let mut v = vec![0.0; rows * self.k];
        self.fill_tile(row0, rows, &mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_deterministic() {
        let m = RowMatrix::new(7, 16);
        assert_eq!(m.row(3), m.row(3));
        assert_ne!(m.row(3), m.row(4));
        let m2 = RowMatrix::new(8, 16);
        assert_ne!(m.row(3), m2.row(3));
    }

    #[test]
    fn tile_matches_rows() {
        let m = RowMatrix::new(42, 8);
        let t = m.tile(10, 5);
        for r in 0..5 {
            assert_eq!(&t[r * 8..(r + 1) * 8], m.row(10 + r).as_slice());
        }
    }

    #[test]
    fn entries_look_standard_normal() {
        let m = RowMatrix::new(1, 64);
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        let n = 2000usize;
        for i in 0..n {
            for &v in &m.row(i) {
                sum += v as f64;
                sumsq += (v as f64) * (v as f64);
            }
        }
        let cnt = (n * 64) as f64;
        let mean = sum / cnt;
        let var = sumsq / cnt - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn row_independence_across_streams() {
        // Adjacent rows should be (empirically) uncorrelated.
        let m = RowMatrix::new(5, 4096);
        let a = m.row(0);
        let b = m.row(1);
        let dot: f64 = a.iter().zip(&b).map(|(&x, &y)| (x * y) as f64).sum();
        let corr = dot / 4096.0;
        assert!(corr.abs() < 0.06, "corr {corr}");
    }
}
