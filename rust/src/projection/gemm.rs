//! Cache-blocked dense matmul for the pure-Rust projection path:
//! `X[B,k] += U[B,Dt] · R[Dt,k]` accumulated over D-tiles.
//!
//! This is the CPU fallback / oracle for the PJRT artifact (which runs
//! the same contraction through the AOT-compiled HLO). Layout is plain
//! row-major; the kernel blocks over the contraction dimension and
//! unrolls the inner k-loop over 8-wide strips so LLVM autovectorizes.

/// `acc[B,k] += u[B,d] · r[d,k]`, all row-major, f32.
///
/// Register-blocked over the contraction dimension: four rows of `r`
/// fuse into each pass over the accumulator row, quartering the
/// acc-row load/store traffic versus a plain axpy loop (measured ~3.4x
/// end-to-end on the b64·d1024·k256 artifact shape — EXPERIMENTS.md
/// §Perf).
pub fn gemm_acc(u: &[f32], r: &[f32], acc: &mut [f32], b: usize, d: usize, k: usize) {
    assert_eq!(u.len(), b * d);
    assert_eq!(r.len(), d * k);
    assert_eq!(acc.len(), b * k);
    // Block the contraction dim so the active r-slab stays in L1/L2,
    // and the batch dim so each r row is reused across RB data rows
    // from cache rather than re-streamed from memory.
    const DB: usize = 64;
    const RB: usize = 8;
    for d0 in (0..d).step_by(DB) {
        let dend = (d0 + DB).min(d);
        for row0 in (0..b).step_by(RB) {
            let rend = (row0 + RB).min(b);
            for row in row0..rend {
            let urow = &u[row * d..(row + 1) * d];
            let arow = &mut acc[row * k..(row + 1) * k];
            let mut di = d0;
            while di + 4 <= dend {
                let (a0, a1, a2, a3) =
                    (urow[di], urow[di + 1], urow[di + 2], urow[di + 3]);
                if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                    let r0 = &r[di * k..(di + 1) * k];
                    let r1 = &r[(di + 1) * k..(di + 2) * k];
                    let r2 = &r[(di + 2) * k..(di + 3) * k];
                    let r3 = &r[(di + 3) * k..(di + 4) * k];
                    axpy4(a0, r0, a1, r1, a2, r2, a3, r3, arow);
                }
                di += 4;
            }
            while di < dend {
                let uv = urow[di];
                if uv != 0.0 {
                    axpy(uv, &r[di * k..(di + 1) * k], arow);
                }
                di += 1;
            }
            }
        }
    }
}

/// Fused `y += a0·x0 + a1·x1 + a2·x2 + a3·x3` (register blocking: one
/// pass over `y` for four contraction steps).
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn axpy4(
    a0: f32,
    x0: &[f32],
    a1: f32,
    x1: &[f32],
    a2: f32,
    x2: &[f32],
    a3: f32,
    x3: &[f32],
    y: &mut [f32],
) {
    let n = y.len();
    debug_assert!(x0.len() == n && x1.len() == n && x2.len() == n && x3.len() == n);
    // chunks_exact elides bounds checks so LLVM vectorizes the body.
    let mut it = y
        .chunks_exact_mut(8)
        .zip(x0.chunks_exact(8))
        .zip(x1.chunks_exact(8))
        .zip(x2.chunks_exact(8))
        .zip(x3.chunks_exact(8));
    for ((((yo, s0), s1), s2), s3) in it.by_ref() {
        for j in 0..8 {
            yo[j] += a0 * s0[j] + a1 * s1[j] + a2 * s2[j] + a3 * s3[j];
        }
    }
    let tail = n - n % 8;
    for j in tail..n {
        y[j] += a0 * x0[j] + a1 * x1[j] + a2 * x2[j] + a3 * x3[j];
    }
}

/// `y += a · x` over f32 slices (autovectorized).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    // Unrolled strips of 8 help LLVM emit wide vector code.
    for c in 0..chunks {
        let xo = &x[c * 8..c * 8 + 8];
        let yo = &mut y[c * 8..c * 8 + 8];
        yo[0] += a * xo[0];
        yo[1] += a * xo[1];
        yo[2] += a * xo[2];
        yo[3] += a * xo[3];
        yo[4] += a * xo[4];
        yo[5] += a * xo[5];
        yo[6] += a * xo[6];
        yo[7] += a * xo[7];
    }
    for i in chunks * 8..n {
        y[i] += a * x[i];
    }
}

/// Naive reference for tests.
pub fn gemm_naive(u: &[f32], r: &[f32], acc: &mut [f32], b: usize, d: usize, k: usize) {
    for row in 0..b {
        for di in 0..d {
            let uv = u[row * d + di];
            for col in 0..k {
                acc[row * k + col] += uv * r[di * k + col];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mathx::Pcg64;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut g = Pcg64::new(seed, 0);
        (0..n).map(|_| g.next_f64() as f32 - 0.5).collect()
    }

    #[test]
    fn blocked_matches_naive() {
        for &(b, d, k) in &[(1usize, 1usize, 1usize), (3, 17, 5), (8, 100, 33), (16, 256, 64)] {
            let u = randv(b * d, 1);
            let r = randv(d * k, 2);
            let mut a1 = vec![0.0f32; b * k];
            let mut a2 = vec![0.0f32; b * k];
            gemm_acc(&u, &r, &mut a1, b, d, k);
            gemm_naive(&u, &r, &mut a2, b, d, k);
            for (x, y) in a1.iter().zip(&a2) {
                assert!((x - y).abs() < 1e-3, "({b},{d},{k}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn accumulates_rather_than_overwrites() {
        let u = randv(2 * 4, 3);
        let r = randv(4 * 3, 4);
        let mut acc = vec![1.0f32; 2 * 3];
        let mut expect = vec![1.0f32; 2 * 3];
        gemm_acc(&u, &r, &mut acc, 2, 4, 3);
        gemm_naive(&u, &r, &mut expect, 2, 4, 3);
        for (x, y) in acc.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn axpy_tail_handling() {
        let x: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let mut y = vec![1.0f32; 13];
        axpy(2.0, &x, &mut y);
        for (i, &v) in y.iter().enumerate() {
            assert_eq!(v, 1.0 + 2.0 * i as f32);
        }
    }
}
