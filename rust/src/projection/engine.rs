//! The [`Projector`]: maps raw data vectors to projected coordinates
//! `x = u·R ∈ R^k`, batched, for dense or sparse inputs, on either the
//! pure-Rust GEMM path or the AOT PJRT artifact path.
//!
//! Both paths compute the *identical* numbers (same virtual `R` from
//! [`super::matrix::RowMatrix`]); the PJRT path tiles the contraction
//! over fixed artifact shapes `(b_tile, d_tile, k)` with zero-padding on
//! the data side, which changes nothing (padded rows of `u` are zero).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::gemm::gemm_acc;
use super::matrix::RowMatrix;
use super::sparse::{accumulate_sign_row, MatrixKind};
use crate::runtime::{ArtifactId, PjrtRuntime};

/// Which compute path executes the projection contraction.
#[derive(Clone)]
pub enum Backend {
    /// Pure-Rust blocked GEMM (always available; the oracle).
    Pure,
    /// AOT PJRT artifacts, falling back to [`Backend::Pure`] per call
    /// when the required artifact shape is absent.
    Pjrt(Arc<PjrtRuntime>),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Pure => write!(f, "Pure"),
            Backend::Pjrt(_) => write!(f, "Pjrt"),
        }
    }
}

/// Projection configuration.
#[derive(Clone, Debug)]
pub struct ProjectionConfig {
    /// Number of projections `k` (the sketch width).
    pub k: usize,
    /// Seed of the virtual projection matrix `R`.
    pub seed: u64,
    /// Contraction tile: rows of `R` processed per step (must match the
    /// AOT artifact `d` for the PJRT path).
    pub d_tile: usize,
    /// Batch tile: data vectors per dispatch (artifact `b`).
    pub b_tile: usize,
    /// Max R-tiles kept in the tile cache (each is `d_tile·k` f32).
    pub max_cached_tiles: usize,
    /// Which matrix the projection draws from (Gaussian by default;
    /// [`MatrixKind::SignSparse`] trades estimator variance for
    /// multiply-free O(nnz) ingest).
    pub kind: MatrixKind,
}

impl Default for ProjectionConfig {
    fn default() -> Self {
        ProjectionConfig {
            k: 256,
            seed: 0,
            d_tile: 1024,
            b_tile: 64,
            max_cached_tiles: 64,
            kind: MatrixKind::Gaussian,
        }
    }
}

/// Batched random-projection engine. Cheap to clone-by-Arc; thread-safe.
#[derive(Debug)]
pub struct Projector {
    pub cfg: ProjectionConfig,
    matrix: RowMatrix,
    backend: Backend,
    /// Cache of materialized R tiles keyed by tile index.
    tiles: Mutex<HashMap<usize, Arc<Vec<f32>>>>,
}

impl Projector {
    /// Pure-Rust CPU projector.
    pub fn new_cpu(cfg: ProjectionConfig) -> Self {
        let matrix = RowMatrix::new(cfg.seed, cfg.k);
        Projector {
            matrix,
            backend: Backend::Pure,
            tiles: Mutex::new(HashMap::new()),
            cfg,
        }
    }

    /// PJRT-backed projector (falls back to pure Rust per call when the
    /// artifact for the configured shape is missing).
    pub fn new_pjrt(cfg: ProjectionConfig, rt: Arc<PjrtRuntime>) -> Self {
        let matrix = RowMatrix::new(cfg.seed, cfg.k);
        Projector {
            matrix,
            backend: Backend::Pjrt(rt),
            tiles: Mutex::new(HashMap::new()),
            cfg,
        }
    }

    pub fn backend(&self) -> &Backend {
        &self.backend
    }

    /// The virtual projection matrix.
    pub fn matrix(&self) -> &RowMatrix {
        &self.matrix
    }

    /// True when the PJRT path will actually be used for batch work.
    pub fn pjrt_active(&self) -> bool {
        if self.cfg.kind != MatrixKind::Gaussian {
            return false; // sign-sparse runs its own CPU kernel
        }
        match &self.backend {
            Backend::Pure => false,
            Backend::Pjrt(rt) => rt.has(&ArtifactId::proj_acc(
                self.cfg.b_tile,
                self.cfg.d_tile,
                self.cfg.k,
            )),
        }
    }

    fn tile(&self, t: usize) -> Arc<Vec<f32>> {
        let mut cache = self.tiles.lock().unwrap();
        if let Some(tile) = cache.get(&t) {
            return tile.clone();
        }
        if cache.len() >= self.cfg.max_cached_tiles {
            cache.clear(); // simple wholesale eviction; tiles regenerate
        }
        let tile = Arc::new(self.matrix.tile(t * self.cfg.d_tile, self.cfg.d_tile));
        cache.insert(t, tile.clone());
        tile
    }

    /// Project one dense vector (any `D`).
    pub fn project_dense(&self, u: &[f32]) -> Vec<f32> {
        self.project_batch(u, 1, u.len())
    }

    /// Project variable-length vectors as one batch: rows are zero-padded
    /// to the longest vector, which does not change their projections
    /// (padded coordinates contribute nothing — see the
    /// `padding_invariance` test). This is the one batch-assembly path
    /// shared by the dynamic batcher and the bulk-ingest handler, so the
    /// two cannot drift apart. Returns `x[b, k]`.
    pub fn project_ragged<'a, I>(&self, vectors: I, b: usize) -> Vec<f32>
    where
        I: Iterator<Item = &'a [f32]>,
    {
        let mut u: Vec<f32> = Vec::new();
        let mut d = 1usize;
        let mut rows: Vec<&[f32]> = Vec::with_capacity(b);
        for v in vectors {
            d = d.max(v.len());
            rows.push(v);
        }
        assert_eq!(rows.len(), b, "ragged batch row count mismatch");
        u.resize(b * d, 0.0);
        for (row, v) in rows.iter().enumerate() {
            u[row * d..row * d + v.len()].copy_from_slice(v);
        }
        self.project_batch(&u, b, d)
    }

    /// Project a row-major batch `u[b, d]` → `x[b, k]`.
    pub fn project_batch(&self, u: &[f32], b: usize, d: usize) -> Vec<f32> {
        assert_eq!(u.len(), b * d);
        if let MatrixKind::SignSparse { s } = self.cfg.kind {
            // Dense input on a sign-sparse collection runs the same
            // per-nonzero kernel the CSR path uses (ascending column
            // order), so the two ingest paths are bit-identical.
            let k = self.cfg.k;
            let mut acc = vec![0.0f32; b * k];
            for row in 0..b {
                let arow = &mut acc[row * k..(row + 1) * k];
                for (di, &v) in u[row * d..(row + 1) * d].iter().enumerate() {
                    if v != 0.0 {
                        accumulate_sign_row(self.cfg.seed, s, di, v, arow);
                    }
                }
            }
            return acc;
        }
        match &self.backend {
            Backend::Pjrt(rt) => {
                let id = ArtifactId::proj_acc(self.cfg.b_tile, self.cfg.d_tile, self.cfg.k);
                if rt.has(&id) {
                    return self
                        .project_batch_pjrt(rt, &id, u, b, d)
                        .expect("PJRT projection failed after artifact presence check");
                }
                self.project_batch_pure(u, b, d)
            }
            Backend::Pure => self.project_batch_pure(u, b, d),
        }
    }

    fn project_batch_pure(&self, u: &[f32], b: usize, d: usize) -> Vec<f32> {
        let k = self.cfg.k;
        let dt = self.cfg.d_tile;
        let mut acc = vec![0.0f32; b * k];
        let n_tiles = d.div_ceil(dt);
        let mut padded = vec![0.0f32; b * dt];
        for t in 0..n_tiles {
            let d0 = t * dt;
            let cols = (d - d0).min(dt);
            let tile = self.tile(t);
            if cols == dt {
                // Strided view: gather the tile's columns of u.
                for row in 0..b {
                    padded[row * dt..(row + 1) * dt]
                        .copy_from_slice(&u[row * d + d0..row * d + d0 + dt]);
                }
            } else {
                padded.fill(0.0);
                for row in 0..b {
                    padded[row * dt..row * dt + cols]
                        .copy_from_slice(&u[row * d + d0..row * d + d0 + cols]);
                }
            }
            gemm_acc(&padded, &tile, &mut acc, b, dt, k);
        }
        acc
    }

    fn project_batch_pjrt(
        &self,
        rt: &PjrtRuntime,
        id: &ArtifactId,
        u: &[f32],
        b: usize,
        d: usize,
    ) -> crate::Result<Vec<f32>> {
        let k = self.cfg.k;
        let bt = self.cfg.b_tile;
        let dt = self.cfg.d_tile;
        let n_tiles = d.div_ceil(dt);
        let mut out = vec![0.0f32; b * k];
        let mut ublock = vec![0.0f32; bt * dt];
        for b0 in (0..b).step_by(bt) {
            let rows = (b - b0).min(bt);
            let mut acc = vec![0.0f32; bt * k];
            for t in 0..n_tiles {
                let d0 = t * dt;
                let cols = (d - d0).min(dt);
                ublock.fill(0.0);
                for r in 0..rows {
                    ublock[r * dt..r * dt + cols]
                        .copy_from_slice(&u[(b0 + r) * d + d0..(b0 + r) * d + d0 + cols]);
                }
                let tile = self.tile(t);
                let lit_u = PjrtRuntime::literal_f32(&ublock, &[bt as i64, dt as i64])?;
                let lit_r = PjrtRuntime::literal_f32(&tile, &[dt as i64, k as i64])?;
                let lit_a = PjrtRuntime::literal_f32(&acc, &[bt as i64, k as i64])?;
                let outs = rt.execute(id, &[lit_u, lit_r, lit_a])?;
                acc = PjrtRuntime::to_vec_f32(&outs[0])?;
            }
            out[b0 * k..(b0 + rows) * k].copy_from_slice(&acc[..rows * k]);
        }
        Ok(out)
    }

    /// Project a sparse vector given as parallel (indices, values): only
    /// the touched rows of `R` are generated, so cost is O(nnz·k), not
    /// O(d·k). This is the path for the high-dimensional sparse datasets
    /// of Section 6 (URL: D ≈ 3.2M). Byte-identical to projecting the
    /// densified vector through [`Projector::project_batch`].
    pub fn project_sparse(&self, idx: &[u32], val: &[f32]) -> Vec<f32> {
        let mut acc = vec![0.0f32; self.cfg.k];
        let mut scratch = Vec::new();
        self.project_csr_row_into(idx, val, &mut scratch, &mut acc);
        acc
    }

    /// Allocation-free core of [`Projector::project_sparse`]: accumulate
    /// one CSR row (strictly increasing `idx`) into a caller-zeroed
    /// `acc` of length `k`, reusing `scratch` across calls. Dispatches
    /// on [`ProjectionConfig::kind`]; both kinds replay the exact
    /// operation sequence of their dense-input counterpart, keeping the
    /// sparse and dense ingest paths bit-identical.
    pub fn project_csr_row_into(
        &self,
        idx: &[u32],
        val: &[f32],
        scratch: &mut Vec<f32>,
        acc: &mut [f32],
    ) {
        assert_eq!(idx.len(), val.len());
        match self.cfg.kind {
            MatrixKind::Gaussian => super::sparse::project_csr_row_into(
                &self.matrix,
                self.cfg.d_tile,
                idx,
                val,
                scratch,
                acc,
            ),
            MatrixKind::SignSparse { s } => {
                assert_eq!(acc.len(), self.cfg.k, "accumulator width mismatch");
                for (&i, &v) in idx.iter().zip(val) {
                    if v != 0.0 {
                        accumulate_sign_row(self.cfg.seed, s, i as usize, v, acc);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mathx::Pcg64;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut g = Pcg64::new(seed, 0);
        (0..n).map(|_| (g.next_f64() as f32 - 0.5) * 2.0).collect()
    }

    fn cfg(k: usize, dt: usize) -> ProjectionConfig {
        ProjectionConfig {
            k,
            seed: 11,
            d_tile: dt,
            b_tile: 4,
            max_cached_tiles: 8,
            kind: MatrixKind::Gaussian,
        }
    }

    #[test]
    fn batch_matches_rowwise_oracle() {
        let p = Projector::new_cpu(cfg(16, 32));
        let (b, d) = (5usize, 100usize);
        let u = randv(b * d, 3);
        let x = p.project_batch(&u, b, d);
        // Oracle: x[row] = Σ_i u[row,i] · R_row(i)
        for row in 0..b {
            let mut want = vec![0.0f64; 16];
            for i in 0..d {
                let rrow = p.matrix().row(i);
                for j in 0..16 {
                    want[j] += (u[row * d + i] * rrow[j]) as f64;
                }
            }
            for j in 0..16 {
                assert!(
                    (x[row * 16 + j] as f64 - want[j]).abs() < 1e-3,
                    "row {row} col {j}"
                );
            }
        }
    }

    #[test]
    fn sparse_matches_dense() {
        let p = Projector::new_cpu(cfg(24, 64));
        let d = 300usize;
        let mut dense = vec![0.0f32; d];
        let idx = vec![3u32, 77, 150, 299];
        let val = vec![0.5f32, -1.0, 2.0, 0.25];
        for (&i, &v) in idx.iter().zip(&val) {
            dense[i as usize] = v;
        }
        let xs = p.project_sparse(&idx, &val);
        let xd = p.project_dense(&dense);
        // Bit-identical, not merely close: the gather kernel replays the
        // dense GEMM's exact operation sequence.
        assert_eq!(xs, xd);
    }

    #[test]
    fn sign_sparse_dense_and_csr_inputs_agree_bitwise() {
        let p = Projector::new_cpu(ProjectionConfig {
            kind: MatrixKind::SignSparse { s: 3 },
            ..cfg(32, 64)
        });
        let d = 500usize;
        let mut dense = vec![0.0f32; d];
        let idx = vec![0u32, 63, 64, 128, 499];
        let val = vec![1.0f32, -0.5, 2.0, 0.125, -4.0];
        for (&i, &v) in idx.iter().zip(&val) {
            dense[i as usize] = v;
        }
        let xs = p.project_sparse(&idx, &val);
        let xd = p.project_dense(&dense);
        assert_eq!(xs, xd);
        // Batch membership must not change a row's projection.
        let mut two = dense.clone();
        two.extend_from_slice(&dense);
        let xb = p.project_batch(&two, 2, d);
        assert_eq!(&xb[..32], xs.as_slice());
        assert_eq!(&xb[32..], xs.as_slice());
    }

    #[test]
    fn ragged_batch_matches_rowwise_dense() {
        let p = Projector::new_cpu(cfg(12, 32));
        let vs: Vec<Vec<f32>> = vec![randv(10, 1), randv(40, 2), vec![], randv(33, 3)];
        let x = p.project_ragged(vs.iter().map(|v| v.as_slice()), vs.len());
        assert_eq!(x.len(), vs.len() * 12);
        for (row, v) in vs.iter().enumerate() {
            let want = p.project_dense(v);
            for j in 0..12 {
                assert!(
                    (x[row * 12 + j] - want[j]).abs() < 1e-4,
                    "row {row} col {j}"
                );
            }
        }
    }

    #[test]
    fn padding_invariance() {
        // Appending zero dims must not change the projection.
        let p = Projector::new_cpu(cfg(8, 16));
        let u = randv(40, 5);
        let mut u_padded = u.clone();
        u_padded.extend_from_slice(&[0.0; 25]);
        let a = p.project_dense(&u);
        let b = p.project_dense(&u_padded);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn projections_preserve_inner_product_in_expectation() {
        // JL property sanity: E[⟨x_u, x_v⟩/k] = ⟨u, v⟩.
        let p = Projector::new_cpu(ProjectionConfig {
            k: 4096,
            seed: 2,
            d_tile: 64,
            b_tile: 4,
            max_cached_tiles: 4,
            kind: MatrixKind::Gaussian,
        });
        let d = 32;
        let (u, v) = crate::data::pairs::unit_pair_with_rho(d, 0.7, 99);
        let xu = p.project_dense(&u);
        let xv = p.project_dense(&v);
        let dot: f64 = xu.iter().zip(&xv).map(|(&a, &b)| (a * b) as f64).sum();
        let est = dot / 4096.0;
        assert!((est - 0.7).abs() < 0.06, "JL estimate {est}");
    }

    #[test]
    fn tile_cache_eviction_consistent() {
        let p = Projector::new_cpu(ProjectionConfig {
            k: 8,
            seed: 4,
            d_tile: 16,
            b_tile: 2,
            max_cached_tiles: 2,
            kind: MatrixKind::Gaussian,
        });
        let u = randv(200, 6);
        let a = p.project_dense(&u);
        let b = p.project_dense(&u); // tiles evicted + regenerated
        assert_eq!(a, b);
    }
}
