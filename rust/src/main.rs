//! `crp` — CLI for the Coding-for-Random-Projections system.
//!
//! Subcommands map onto DESIGN.md's per-experiment index:
//!
//! * `figures`     — regenerate paper figures 1–14 (CSV + text)
//! * `mc-variance` — Monte-Carlo validation of Theorems 2–4 (+ `--mle`)
//! * `lsh-eval`    — recall/probe-cost comparison of coding schemes
//! * `serve`       — run the sketch service (Layer-3 coordinator)
//! * `bench-serve` — loadgen against a running service
//! * `topk`        — arena scan demo: top-k over a synthetic sketch corpus
//! * `metrics`     — dump a server's Prometheus-style exposition page
//! * `promote`     — flip a read-only replica into a writable primary
//! * `slow`        — dump a server's in-memory slow-query ring
//! * `artifacts`   — list/verify AOT artifacts
//! * `estimate`    — one-shot similarity estimation demo
//!
//! Flags are `--name value` (no external CLI crate is vendored in this
//! environment; parsing is in [`args`]).

use std::sync::Arc;

use crp::coding::{CodingParams, Scheme};
use crp::figures::{run_figure, ALL_FIGURES};
use crp::projection::{ProjectionConfig, Projector};

/// Minimal `--flag value` argument parser.
mod args {
    use std::collections::HashMap;

    pub struct Args {
        pub cmd: String,
        /// Optional bare word after the command (`crp collection list`).
        pub sub: Option<String>,
        flags: HashMap<String, String>,
        bools: std::collections::HashSet<String>,
    }

    impl Args {
        pub fn parse(bool_flags: &[&str]) -> anyhow::Result<Self> {
            let mut argv = std::env::args().skip(1).peekable();
            let cmd = argv.next().unwrap_or_else(|| "help".to_string());
            let sub = match argv.peek() {
                Some(a) if !a.starts_with("--") => argv.next(),
                _ => None,
            };
            let mut flags = HashMap::new();
            let mut bools = std::collections::HashSet::new();
            while let Some(a) = argv.next() {
                let name = a
                    .strip_prefix("--")
                    .ok_or_else(|| anyhow::anyhow!("expected --flag, got {a:?}"))?
                    .to_string();
                if bool_flags.contains(&name.as_str()) {
                    bools.insert(name);
                } else {
                    let v = argv
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?;
                    flags.insert(name, v);
                }
            }
            Ok(Args {
                cmd,
                sub,
                flags,
                bools,
            })
        }

        pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
        where
            T::Err: std::fmt::Display,
        {
            match self.flags.get(name) {
                None => Ok(default),
                Some(v) => v
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --{name} {v:?}: {e}")),
            }
        }

        pub fn get_str(&self, name: &str, default: &str) -> String {
            self.flags
                .get(name)
                .cloned()
                .unwrap_or_else(|| default.to_string())
        }

        pub fn get_opt(&self, name: &str) -> Option<&str> {
            self.flags.get(name).map(|s| s.as_str())
        }

        pub fn flag(&self, name: &str) -> bool {
            self.bools.contains(name)
        }
    }
}

fn parse_scheme(s: &str) -> crp::Result<Scheme> {
    Ok(match s {
        "uniform" | "hw" | "h_w" => Scheme::Uniform,
        "offset" | "hwq" | "h_wq" | "window-offset" => Scheme::WindowOffset,
        "two-bit" | "hw2" | "h_w2" | "2bit" => Scheme::TwoBit,
        "one-bit" | "h1" | "h_1" | "1bit" | "sign" => Scheme::OneBit,
        other => anyhow::bail!("unknown scheme {other:?} (uniform|offset|two-bit|one-bit)"),
    })
}

/// Build the legacy single-collection durability config from
/// `--snapshot` / `--wal-dir`; either flag alone implies the other next
/// to it (`<wal-dir>/snapshot.bin`, `<snapshot>.wal/`). Neither flag
/// means no legacy durability (use `--data-dir` for the per-collection
/// layout).
fn durability_config(
    a: &args::Args,
    checkpoint_every: u64,
    fsync: crp::coordinator::FsyncPolicy,
) -> crp::Result<Option<crp::coordinator::DurabilityConfig>> {
    use std::path::PathBuf;
    let snapshot = a.get_opt("snapshot").map(PathBuf::from);
    let wal_dir = a.get_opt("wal-dir").map(PathBuf::from);
    let (snapshot, wal_dir) = match (snapshot, wal_dir) {
        (None, None) => return Ok(None),
        (Some(s), Some(w)) => (s, w),
        (Some(s), None) => {
            let mut w = s.as_os_str().to_os_string();
            w.push(".wal");
            (s, PathBuf::from(w))
        }
        (None, Some(w)) => (w.join("snapshot.bin"), w),
    };
    Ok(Some(crp::coordinator::DurabilityConfig {
        snapshot,
        wal_dir,
        checkpoint_every,
        fsync,
    }))
}

const HELP: &str = "\
crp — Coding for Random Projections (ICML 2014) reproduction

USAGE: crp <command> [subcommand] [--flag value ...]

COMMANDS:
  figures      --fig N --scale S --out DIR      regenerate paper figures (default: all)
  mc-variance  --k K --reps R --w W [--mle]     Monte-Carlo check of Theorems 2-4
  lsh-eval     --corpus N --dim D --tables T --k-per-table K --queries Q
  serve        --addr A --k K --scheme S --w W [--pjrt]
               [--drain-threshold N]  ingest-epoch size before a bulk fold
               [--max-conns N]        concurrent-connection cap (0 = unlimited)
               [--server-mode M]      threads (default) or reactor — see SERVING
               [--reactor-threads N]  reactor event loops, each with its
                 own SO_REUSEPORT listener (default min(4, cores);
                 0 = the single pre-sharding loop)
               [--reactor-workers N]  worker threads executing fused
                 bulk runs off the event loops (0 = inline, the default)
               [--data-dir DIR]       durable multi-collection root: every
                 collection persists under DIR/<name>/{snap,wal} and a
                 CRC-checked DIR/MANIFEST records each collection's coding
                 config, so restart rebuilds the whole registry
               [--snapshot F --wal-dir D]  legacy single-collection
                 durability for `default` only (exclusive with --data-dir)
               [--checkpoint-every N] checkpoint each N logged rows
                 (0 = only explicit Persist requests / shutdown)
               [--fsync always|os|group:<ms>]  WAL durability policy
               [--metrics-addr H:P]   serve GET /metrics (Prometheus text)
               [--log-level L]        error|warn|info|debug (overrides CRP_LOG)
               [--slow-query-us N]    log requests slower than N us (0 = off)
               [--trace-sample N]     debug-trace every Nth request (0 = off)
               [--conn-timeout-ms N]  per-connection socket read/write
                 timeout; an idle client is disconnected after N ms
                 (0 = off, the default)
               [--replicate-from A]   run as a read-only replica of the
                 primary at A (in-memory only; no --data-dir/--snapshot)
               [--repl-lag-cap B]     replication lag cap in bytes: the
                 primary retires WAL segments past it (replica re-
                 bootstraps), and a replica over it reports not-ready
  collection   create --addr A --name N --scheme S --w W --k K --seed X
                      [--checkpoint-every N]  per-collection checkpoint
                      cadence (0 = the server's global --checkpoint-every)
                      [--matrix-kind gaussian|sign-sparse [--sign-s S]]
                      projection-matrix family (see SPARSE INGEST)
               drop   --addr A --name N
               list   --addr A
               manage named collections on a running server; each owns
               its own (scheme, w, k, seed) coding choice
  stats        --addr A [--watch]  aggregate service counters, the
               per-request latency table (count, mean, p50, p99 per
               request kind), and the per-collection breakdown (rows,
               pending, wal bytes, index buckets); --watch clears the
               screen and refreshes every second until interrupted
  metrics      --addr A   dump the full Prometheus-style exposition
               page over the protocol (same text --metrics-addr
               serves over HTTP)
  promote      --addr A   flip a replica into a writable primary
               (no-op with a note if the server never replicated)
  slow         --addr A [--max N]   dump the server's slow-query ring
               (most recent N entries; 0 or omitted = the whole ring)
  register     --addr A [--collection C] --id I (--vec \"f,f,...\" | --dim D --vec-seed X)
               register one vector over the wire (namespaced); or
               --libsvm FILE [--chunk N] [--id-prefix P] [--dim D]
               bulk sparse ingest: stream a libsvm/svmlight file
               through RegisterSparse frames of N rows (default 1024),
               parsed and shipped chunk by chunk (peak memory is one
               chunk; the summary reports peak RSS), row r stored as
               id \"<P><r>\" (see SPARSE INGEST)
  recover      --snapshot F --wal-dir D   replay a snapshot + WAL offline
               and print recovery stats (rows, records, torn tail)
  bench-serve  --addr A --n N --dim D --connections C [--collection C]
               [--queries Q --top T [--approx] [--probes P]]  after the
               ingest phase, send Q TopK (or ApproxTopK) queries and
               report query throughput
  topk         --sketches N --k K --scheme S --w W --top T --queries Q --threads P --rho R
               scan-engine demo: exact top-k over a packed-code arena;
               --approx [--probes P] runs the banded-index demo instead
               (planted neighbors, recall@top vs the exact oracle,
               speedup); with --addr [--collection C] it sends random
               TopK (or, with --approx, ApproxTopK) queries to a
               running server (namespaced)
  artifacts                                      list + compile-check AOT artifacts
  estimate     --rho R --k K --w W --dim D       one-shot estimation demo
  bit-budget   --rho R                            optimized V per bit budget
  help

SCAN KERNELS:
  Scans auto-select the widest collision kernel the CPU supports
  (avx512 > avx2 > sse2 > swar) once per scanner; all tiers rank
  byte-identically. Set CRP_SCAN_KERNEL=swar|sse2|avx2|avx512 to force
  a tier (swar = portable path; an unavailable forced tier falls back
  to auto-selection; avx512 needs AVX512VPOPCNTDQ — Ice Lake / Zen 4+).
  Registration is epoch-buffered: puts never take the scan arena's write
  lock, and each epoch folds in bulk at --drain-threshold pending rows
  (folded by a background maintenance thread, not the crossing writer).

APPROX SEARCH:
  Every collection maintains a banded multi-probe code index over its
  sealed arena: each sketch's packed words are sliced into bands (a few
  codes each, keyed verbatim — no re-hashing) and ApproxTopK reranks
  only the rows sharing a probed bucket with the query, through the
  same SIMD kernels the exact scan uses. Pending (not yet drained)
  rows are always swept exactly, so approximate results are as fresh
  as exact ones, and every returned rho_hat is exact for its id.
  Trade-off dials: more/narrower BANDS raise recall and candidate
  cost; --probes P adds P low-order band-bit flips per band (adjacent
  quantizer bins) — more probes, more recall, more candidates. The
  index shape derives from each collection's (k, bits) and is recorded
  in the MANIFEST; exact TopK stays available as the oracle, and small
  stores fall back to it automatically. At 1e5 rows expect order-of-
  magnitude fewer scored rows at recall@10 >= 0.9 for rho >= 0.9
  neighbors (see `crp topk --approx` and scan_bench).

SPARSE INGEST:
  High-dimensional inputs are usually sparse (the paper's motivating
  datasets reach d = 2^24 with a few hundred nonzeros per row), so
  densifying on the client is the bottleneck long before coding is.
  RegisterSparse ships rows as CSR index:value triplets and the server
  projects each row by gathering only the touched columns — O(nnz x k)
  work and wire bytes instead of O(d x k) — then quantizes and packs
  through the same fused encoder as dense ingest, so the stored code
  is byte-identical to registering the densified vector. `crp register
  --libsvm FILE` streams a whole svmlight/libsvm file this way in
  chunked frames; under the reactor, concurrently-arriving
  RegisterSparse frames coalesce into one bulk ingest like dense
  Register traffic does. A collection can also opt into a sign-sparse
  projection matrix at create time (--matrix-kind sign-sparse
  --sign-s S): entries are +1/-1 with probability 1/(2S) each and 0
  otherwise, so projection is add/subtract only, and the family is
  recorded in the MANIFEST so restarts rebuild the same matrix. Codes
  from a sign-sparse collection differ from a Gaussian collection's by
  design, but dense and sparse ingest into the same collection always
  agree bit for bit.

SERVING:
  --server-mode picks the TCP front-end; both modes speak the same
  frame protocol and answer byte-identically. `threads` (the default)
  spawns one blocking thread per connection — simple and debuggable.
  `reactor` runs epoll event loops (linux x86_64/aarch64 only):
  nonblocking accept, frames parsed in place out of per-connection
  read buffers, pipelined requests dispatched per readiness event,
  concurrently-arriving Register/RegisterSparse/TopK requests
  coalesced into the engine's bulk paths, and gathered response
  writes with backpressure (a slow reader stops being polled for
  input past 1 MiB of queued responses, so it never stalls other
  connections). --reactor-threads N (default min(4, cores)) shards
  the front-end: N event loops each bind their own SO_REUSEPORT
  listener, so the kernel spreads connections across loops with no
  shared accept lock and the loops share nothing on the hot path;
  0 keeps the original single loop. --reactor-workers W hands fused
  bulk runs to a bounded worker pool over per-loop SPSC rings with
  eventfd wakeups — the loop keeps parsing and writing while heavy
  ingest/scan work runs off-loop, with per-connection program order
  and per-frame ack order preserved (0 = run them inline). Each loop
  holds 10k+ connections with flat tail latency and no per-request
  heap allocation at steady state; the crp_reactor_* series on
  /metrics (aggregate plus a {reactor=\"i\"} breakdown per loop,
  offloaded batches, worker queue depth) and `crp stats` show it
  working. --max-conns caps both modes globally; --conn-timeout-ms
  idle disconnects are honored in both (the reactor sweeps idle
  connections off a coarse timer).

COLLECTIONS:
  One server process serves many named collections, each with its own
  coding choice — the paper's point that the scheme is a per-workload
  decision. Legacy clients (no namespace) hit the `default` collection,
  whose coding comes from the serve flags. `crp collection create` adds
  more at runtime; with --data-dir they are durable and survive restarts
  via the MANIFEST. Same ids in different collections never collide.

DURABILITY:
  With --data-dir (or legacy --snapshot/--wal-dir), every acknowledged
  Register/RegisterBatch/Remove is appended to a checksummed WAL before
  the store mutates, and checkpoints rewrite the snapshot as a verbatim
  arena image (CRPSNAP2) then truncate the WAL — restart replays
  snapshot + WAL tail through the bulk ingest path and answers
  byte-identically to the pre-crash server. Checkpoints never hold a
  store lock across disk writes.
  --fsync sets when WAL records reach stable storage: `os` (default)
  flushes to the page cache per record — survives kill -9, not power
  loss; `always` fsyncs per record — full durability, one disk round
  trip per op; `group:<ms>` flushes per record and fsyncs at most once
  per interval — bounds power-loss exposure to one interval at near-`os`
  throughput.

OBSERVABILITY:
  Every request is timed end to end (decode + handle + write) into a
  per-request-kind power-of-two histogram; `crp stats` reports p50/p99
  per kind and `GET /metrics` on --metrics-addr (or `crp metrics`)
  exposes the same data as Prometheus text (version 0.0.4) alongside
  engine histograms: drain/fold and compaction time, WAL append and
  snapshot-write time, ApproxTopK candidate and probe counts — all per
  collection, with zero overhead beyond an atomic add per event.
  Logs are structured key=value lines on stderr, gated by CRP_LOG or
  --log-level (error|warn|info|debug, default info). With
  --slow-query-us N, any request slower than N microseconds emits
  exactly one `target=crp::slow_query` warn line carrying the request
  kind, collection, candidate count, scan-kernel tier, and the
  decode/handle/write stage breakdown; --trace-sample N emits the same
  fields at debug level for every Nth (non-slow) request. The last 128
  slow queries are also kept in an in-memory ring served by `crp slow`.

REPLICATION:
  `crp serve --replicate-from PRIMARY` runs a read-only replica: it
  bootstraps every collection from a primary snapshot (CRPSNAP2 over
  the wire), then tails the primary's WAL in CRC-checked chunks and
  applies records through the same ingest path recovery uses — so a
  caught-up replica answers Knn/TopK/ApproxTopK/Estimate byte-
  identically to the primary. Writes are rejected with a redirect to
  the primary until `crp promote` flips the replica writable (manual
  failover). The link self-heals: lost connections reconnect with
  jittered exponential backoff, torn or corrupt chunks are rejected
  wholesale and re-fetched, and a replica that falls behind the
  primary's retained WAL (bounded by --repl-lag-cap, default 256 MiB)
  re-bootstraps from a fresh snapshot automatically. Lag is visible as
  crp_replication_* gauges on /metrics, in `crp stats`, and through
  GET /readyz (503 while bootstrapping or over the cap); the primary
  never deletes a WAL segment an attached replica still needs unless
  retention would exceed the cap.
";

fn main() -> crp::Result<()> {
    let a = args::Args::parse(&["mle", "pjrt", "approx", "watch"])?;
    match a.cmd.as_str() {
        "figures" => {
            let scale: f64 = a.get("scale", 0.25)?;
            let out = a.get_str("out", "results");
            let figs: Vec<u32> = match a.get_opt("fig") {
                Some(f) => vec![f.parse()?],
                None => ALL_FIGURES.to_vec(),
            };
            for f in figs {
                eprintln!("-- figure {f}");
                for t in run_figure(f, scale)? {
                    let path = t.write_csv(&out)?;
                    println!("{}", t.render_text(12));
                    eprintln!("   wrote {}", path.display());
                }
            }
        }
        "mc-variance" => {
            let k: usize = a.get("k", 1024)?;
            let reps: u64 = a.get("reps", 400)?;
            let w: f64 = a.get("w", 0.75)?;
            let out = a.get_str("out", "results");
            let t = crp::figures::mc::mc_variance_table(k, reps, w, 20140601);
            t.write_csv(&out)?;
            println!("{}", t.render_text(24));
            if a.flag("mle") {
                let t = crp::figures::mc::mc_mle_table(k, reps.min(200), w, 20140602);
                t.write_csv(&out)?;
                println!("{}", t.render_text(12));
            }
        }
        "lsh-eval" => {
            let corpus: usize = a.get("corpus", 2000)?;
            let dim: usize = a.get("dim", 64)?;
            let tables: usize = a.get("tables", 8)?;
            let kpt: usize = a.get("k-per-table", 8)?;
            let queries: usize = a.get("queries", 100)?;
            // Table keys are exact band values read out of the packed
            // words, so a key must fit one u64 at the widest scheme in
            // the comparison (4 bits/code at w = 1.0).
            anyhow::ensure!(
                (1..=16).contains(&kpt),
                "--k-per-table must be in [1, 16] (a table key of k codes \
                 x up to 4 bits must fit a 64-bit band)"
            );
            println!(
                "{:<14} {:>6} {:>12} {:>16}",
                "scheme", "w", "recall@10", "candidate_frac"
            );
            for (scheme, w) in [
                (Scheme::Uniform, 1.0),
                (Scheme::WindowOffset, 1.0),
                (Scheme::TwoBit, 0.75),
                (Scheme::OneBit, 0.0),
            ] {
                let params = crp::lsh::LshParams {
                    coding: CodingParams::new(scheme, w),
                    k_per_table: kpt,
                    n_tables: tables,
                    seed: 7,
                };
                let r = crp::lsh::eval::evaluate_lsh(params, corpus, dim, queries, 99);
                println!(
                    "{:<14} {:>6.2} {:>12.3} {:>16.4}",
                    r.scheme, r.w, r.recall_at_10, r.candidate_frac
                );
            }
        }
        "serve" => {
            let addr = a.get_str("addr", "127.0.0.1:7474");
            let k: usize = a.get("k", 256)?;
            let scheme = parse_scheme(&a.get_str("scheme", "two-bit"))?;
            let w: f64 = a.get("w", 0.75)?;
            let drain_threshold: usize = a.get("drain-threshold", 4096)?;
            let max_conns: usize = a.get("max-conns", 1024)?;
            let server_mode: crp::coordinator::ServerMode =
                a.get("server-mode", Default::default())?;
            let reactor_threads: usize = a.get(
                "reactor-threads",
                crp::coordinator::reactor::default_reactor_threads(),
            )?;
            let reactor_workers: usize = a.get("reactor-workers", 0usize)?;
            let fsync = crp::coordinator::FsyncPolicy::parse(&a.get_str("fsync", "os"))?;
            let checkpoint_every: u64 = a.get("checkpoint-every", 100_000u64)?;
            let cfg = ProjectionConfig {
                k,
                seed: 0,
                ..Default::default()
            };
            let projector = if a.flag("pjrt") {
                let rt = Arc::new(crp::runtime::PjrtRuntime::cpu_default()?);
                eprintln!("PJRT platform: {}", rt.platform_name());
                Projector::new_pjrt(cfg, rt)
            } else {
                Projector::new_cpu(cfg)
            };
            let coding = CodingParams::new(scheme, w);
            let kernel = crp::scan::CollisionKernel::select(coding.bits_per_code());
            eprintln!(
                "serving on {addr} (k={k}, scheme={}, w={w}, pjrt_active={}, \
                 scan_kernel={}, drain_threshold={drain_threshold}, \
                 max_conns={max_conns}, server_mode={}, reactor_threads={reactor_threads}, \
                 reactor_workers={reactor_workers})",
                scheme.label(),
                projector.pjrt_active(),
                kernel.kind().label(),
                server_mode.label()
            );
            let data_dir = a.get_opt("data-dir").map(std::path::PathBuf::from);
            let durability = durability_config(&a, checkpoint_every, fsync)?;
            let conn_timeout_ms: u64 = a.get("conn-timeout-ms", 0u64)?;
            let replicate_from = a.get_opt("replicate-from").map(str::to_string);
            let repl_lag_cap: u64 = a.get(
                "repl-lag-cap",
                crp::coordinator::durability::DEFAULT_REPL_LAG_CAP,
            )?;
            if let Some(primary) = &replicate_from {
                eprintln!(
                    "replication: read-only replica of {primary} \
                     (lag cap {repl_lag_cap} bytes; `crp promote` to fail over)"
                );
            }
            if let Some(root) = &data_dir {
                anyhow::ensure!(
                    durability.is_none(),
                    "--data-dir and --snapshot/--wal-dir are mutually exclusive"
                );
                eprintln!(
                    "durability: data dir {} (per-collection snap+wal, MANIFEST, \
                     checkpoint every {} rows, fsync {})",
                    root.display(),
                    checkpoint_every,
                    fsync.label()
                );
            }
            if let Some(d) = &durability {
                eprintln!(
                    "durability: snapshot {} + wal {} (checkpoint every {} rows, fsync {})",
                    d.snapshot.display(),
                    d.wal_dir.display(),
                    d.checkpoint_every,
                    d.fsync.label()
                );
            }
            let server_cfg = crp::coordinator::ServerConfig {
                addr,
                coding,
                epoch: crp::scan::EpochConfig {
                    drain_threshold,
                    ..Default::default()
                },
                durability,
                data_dir,
                fsync,
                checkpoint_every,
                max_conns,
                server_mode,
                reactor_threads,
                reactor_workers,
                metrics_addr: a.get_opt("metrics-addr").map(str::to_string),
                log_level: a.get_opt("log-level").map(str::to_string),
                slow_query_us: a.get("slow-query-us", 0u64)?,
                trace_sample: a.get("trace-sample", 0u64)?,
                conn_timeout: (conn_timeout_ms > 0)
                    .then(|| std::time::Duration::from_millis(conn_timeout_ms)),
                replicate_from,
                repl_lag_cap,
                ..Default::default()
            };
            crp::coordinator::serve(Arc::new(projector), server_cfg, None)?;
        }
        "collection" => {
            let addr = a.get_str("addr", "127.0.0.1:7474");
            let mut client = crp::coordinator::SketchClient::connect_with_retry(&addr, 5)?;
            match a.sub.as_deref() {
                Some("create") => {
                    let name = a.get_str("name", "");
                    anyhow::ensure!(!name.is_empty(), "collection create needs --name");
                    let scheme = parse_scheme(&a.get_str("scheme", "two-bit"))?;
                    let w: f64 = a.get("w", 0.75)?;
                    let k: u64 = a.get("k", 256)?;
                    let seed: u64 = a.get("seed", 0)?;
                    let every: u64 = a.get("checkpoint-every", 0u64)?;
                    let kind = match a.get_str("matrix-kind", "gaussian").as_str() {
                        "gaussian" | "dense" => crp::projection::MatrixKind::Gaussian,
                        "sign-sparse" | "sign" | "achlioptas" => {
                            let s: u32 = a.get("sign-s", 4u32)?;
                            crp::projection::MatrixKind::SignSparse { s }
                        }
                        other => {
                            anyhow::bail!(
                                "unknown --matrix-kind {other:?} (gaussian|sign-sparse)"
                            )
                        }
                    };
                    client
                        .create_collection_with_kind(&name, scheme, w, k, seed, every, kind)?;
                    println!(
                        "created collection {name:?} (scheme={}, w={w}, k={k}, seed={seed}, \
                         matrix={kind}, checkpoint_every={})",
                        scheme.label(),
                        if every > 0 {
                            every.to_string()
                        } else {
                            "global".to_string()
                        }
                    );
                }
                Some("drop") => {
                    let name = a.get_str("name", "");
                    anyhow::ensure!(!name.is_empty(), "collection drop needs --name");
                    let existed = client.drop_collection(&name)?;
                    println!(
                        "{}",
                        if existed {
                            format!("dropped collection {name:?}")
                        } else {
                            format!("collection {name:?} did not exist")
                        }
                    );
                }
                Some("list") | None => {
                    let collections = client.list_collections()?;
                    println!(
                        "{:<24} {:<8} {:>8} {:>6} {:>8} {:>12} {:>10} {:>8}",
                        "name", "scheme", "w", "bits", "k", "seed", "rows", "durable"
                    );
                    for c in collections {
                        println!(
                            "{:<24} {:<8} {:>8.3} {:>6} {:>8} {:>12} {:>10} {:>8}",
                            c.name,
                            c.scheme.label(),
                            c.w,
                            c.bits,
                            c.k,
                            c.seed,
                            c.rows,
                            if c.durable { "yes" } else { "no" }
                        );
                    }
                }
                Some(other) => anyhow::bail!(
                    "unknown collection subcommand {other:?} (create|drop|list)"
                ),
            }
        }
        "register" => {
            let addr = a.get_str("addr", "127.0.0.1:7474");
            let collection = a.get_opt("collection").map(str::to_string);
            if let Some(path) = a.get_opt("libsvm") {
                return register_libsvm(&a, &addr, collection.as_deref(), path);
            }
            let id = a.get_str("id", "");
            anyhow::ensure!(!id.is_empty(), "register needs --id (or --libsvm FILE)");
            let vector: Vec<f32> = match a.get_opt("vec") {
                Some(csv) => csv
                    .split(',')
                    .map(|t| {
                        t.trim()
                            .parse::<f32>()
                            .map_err(|e| anyhow::anyhow!("bad --vec component {t:?}: {e}"))
                    })
                    .collect::<crp::Result<_>>()?,
                None => {
                    let dim: usize = a.get("dim", 128)?;
                    let seed: u64 = a.get("vec-seed", 1)?;
                    let mut ns = crp::mathx::NormalSampler::new(seed, 1);
                    (0..dim).map(|_| ns.next() as f32).collect()
                }
            };
            let dim = vector.len();
            let mut client = crp::coordinator::SketchClient::connect_with_retry(&addr, 5)?;
            client.register_in(collection.as_deref(), &id, vector)?;
            println!(
                "registered {id:?} (dim {dim}) in collection {:?}",
                collection.as_deref().unwrap_or("default")
            );
        }
        "recover" => {
            let Some(cfg) = durability_config(&a, 0, crp::coordinator::FsyncPolicy::Os)? else {
                anyhow::bail!("recover needs --snapshot and/or --wal-dir");
            };
            let (store, k, bits, st) =
                crp::coordinator::durability::recover(&cfg.snapshot, &cfg.wal_dir)?;
            println!("shape: k={k} @ {bits} bit(s)/code");
            println!("snapshot rows restored: {}", st.snapshot_rows);
            println!(
                "wal: {} segment(s), {} record(s), {} byte(s) replayed{}",
                st.wal_segments,
                st.wal_records,
                st.wal_bytes,
                if st.wal_torn {
                    " (torn tail discarded)"
                } else {
                    ""
                }
            );
            println!("live sketches: {}", st.live);
            let arena = store.arena().expect("recovered store is arena-backed");
            println!(
                "arena: {} rows allocated, {} tombstones, {:.1} MiB packed",
                arena.len() + arena.tombstones(),
                arena.tombstones(),
                arena.storage_bytes() as f64 / (1 << 20) as f64
            );
        }
        "bench-serve" => {
            let addr = a.get_str("addr", "127.0.0.1:7474");
            let n: usize = a.get("n", 1000)?;
            let dim: usize = a.get("dim", 128)?;
            let connections: usize = a.get("connections", 4)?;
            let collection = a.get_opt("collection").map(str::to_string);
            bench_serve(&addr, n, dim, connections, collection.clone())?;
            let queries: usize = a.get("queries", 0)?;
            if queries > 0 {
                let top: u32 = a.get("top", 10u32)?;
                let probes: u32 = a.get("probes", 0u32)?;
                bench_queries(
                    &addr,
                    collection.as_deref(),
                    queries,
                    dim,
                    top,
                    a.flag("approx"),
                    probes,
                )?;
            }
        }
        "stats" => {
            let addr = a.get_str("addr", "127.0.0.1:7474");
            let mut client = crp::coordinator::SketchClient::connect_with_retry(&addr, 5)?;
            if a.flag("watch") {
                loop {
                    let st = client.stats_detailed()?;
                    // Clear the screen and home the cursor between
                    // refreshes so the table repaints in place.
                    print!("\x1b[2J\x1b[H");
                    print_stats(&st);
                    use std::io::Write;
                    std::io::stdout().flush()?;
                    std::thread::sleep(std::time::Duration::from_secs(1));
                }
            }
            print_stats(&client.stats_detailed()?);
        }
        "metrics" => {
            let addr = a.get_str("addr", "127.0.0.1:7474");
            let mut client = crp::coordinator::SketchClient::connect_with_retry(&addr, 5)?;
            print!("{}", client.metrics_text()?);
        }
        "promote" => {
            let addr = a.get_str("addr", "127.0.0.1:7474");
            let mut client = crp::coordinator::SketchClient::connect_with_retry(&addr, 5)?;
            if client.promote()? {
                println!("promoted: {addr} now accepts writes");
            } else {
                println!("{addr} was already a writable primary (no-op)");
            }
        }
        "slow" => {
            let addr = a.get_str("addr", "127.0.0.1:7474");
            let max: u32 = a.get("max", 0u32)?;
            let mut client = crp::coordinator::SketchClient::connect_with_retry(&addr, 5)?;
            let entries = client.slow_queries(max)?;
            if entries.is_empty() {
                println!("slow-query ring is empty (is --slow-query-us set on the server?)");
            } else {
                println!(
                    "{:<8} {:<16} {:<24} {:>12} {:>12}",
                    "seq", "request", "collection", "total_us", "candidates"
                );
                for e in entries {
                    println!(
                        "{:<8} {:<16} {:<24} {:>12} {:>12}",
                        e.seq, e.kind, e.collection, e.total_us, e.candidates
                    );
                }
            }
        }
        "topk" => {
            let top: usize = a.get("top", 10)?;
            let queries: usize = a.get("queries", 20)?;
            let approx = a.flag("approx");
            let probes: usize = a.get("probes", 0)?;
            if let Some(addr) = a.get_opt("addr") {
                // Remote mode: namespaced TopK against a running server.
                let collection = a.get_opt("collection").map(str::to_string);
                let dim: usize = a.get("dim", 128)?;
                let seed: u64 = a.get("seed", 20140601)?;
                run_topk_remote(
                    addr,
                    collection.as_deref(),
                    dim,
                    top,
                    queries,
                    seed,
                    approx,
                    probes as u32,
                )?;
            } else if approx {
                let sketches: usize = a.get("sketches", 100_000)?;
                let k: usize = a.get("k", 256)?;
                let scheme = parse_scheme(&a.get_str("scheme", "two-bit"))?;
                let w: f64 = a.get("w", 0.75)?;
                let rho: f64 = a.get("rho", 0.95)?;
                let seed: u64 = a.get("seed", 20140601)?;
                run_topk_approx_demo(sketches, k, scheme, w, top, queries, rho, probes, seed)?;
            } else {
                let sketches: usize = a.get("sketches", 20_000)?;
                let k: usize = a.get("k", 1024)?;
                let scheme = parse_scheme(&a.get_str("scheme", "one-bit"))?;
                let w: f64 = a.get("w", 0.75)?;
                let threads: usize = a.get("threads", 0)?;
                let rho: f64 = a.get("rho", 0.9)?;
                let seed: u64 = a.get("seed", 20140601)?;
                run_topk_demo(sketches, k, scheme, w, top, queries, threads, rho, seed)?;
            }
        }
        "artifacts" => {
            let reg = crp::runtime::ArtifactRegistry::default_location();
            let list = reg.list();
            if list.is_empty() {
                println!("no artifacts in {:?} — run `make artifacts`", reg.dir());
            } else {
                let rt = crp::runtime::PjrtRuntime::cpu(reg)?;
                println!("PJRT platform: {}", rt.platform_name());
                for id in list {
                    let ok = rt.executable(&id).map(|_| "compiles").unwrap_or("BROKEN");
                    println!("  {:<40} {}", id.0, ok);
                }
            }
        }
        "estimate" => {
            let rho: f64 = a.get("rho", 0.8)?;
            let k: usize = a.get("k", 1024)?;
            let w: f64 = a.get("w", 0.75)?;
            let dim: usize = a.get("dim", 256)?;
            let (u, v) = crp::data::pairs::unit_pair_with_rho(dim, rho, 42);
            let proj = Projector::new_cpu(ProjectionConfig {
                k,
                seed: 0,
                ..Default::default()
            });
            let xu = proj.project_dense(&u);
            let xv = proj.project_dense(&v);
            println!("true rho = {rho}, k = {k}, w = {w}");
            println!(
                "{:<14} {:>10} {:>12} {:>10}",
                "scheme", "rho_hat", "std_err", "bits"
            );
            for scheme in [
                Scheme::Uniform,
                Scheme::WindowOffset,
                Scheme::TwoBit,
                Scheme::OneBit,
            ] {
                let params = CodingParams::new(scheme, w);
                let est = crp::estimator::CollisionEstimator::new(params.clone());
                let e = est.estimate_with_error(&params.encode(&xu), &params.encode(&xv));
                println!(
                    "{:<14} {:>10.4} {:>12.4} {:>10}",
                    scheme.label(),
                    e.rho,
                    e.std_err,
                    params.bits_per_code()
                );
            }
        }
        "bit-budget" => {
            let rho: f64 = a.get("rho", 0.9)?;
            println!("optimized variance factor per bit budget at rho = {rho}:");
            println!("{:<44} {:>5} {:>12}", "scheme", "bits", "V");
            for (name, bits, v) in crp::theory::nonuniform::bit_budget_table(rho) {
                println!("{name:<44} {bits:>5} {v:>12.5}");
            }
        }
        "help" | "--help" | "-h" => print!("{HELP}"),
        other => {
            eprint!("{HELP}");
            anyhow::bail!("unknown command {other:?}");
        }
    }
    Ok(())
}

/// Bulk sparse ingest: stream a libsvm/svmlight file into a running
/// server through `RegisterSparse` frames of `--chunk` rows. Row `r`
/// gets id `<--id-prefix><r>`; wire bytes and server-side projection
/// work both scale with nnz, not the (possibly enormous) dimension.
fn register_libsvm(
    a: &args::Args,
    addr: &str,
    collection: Option<&str>,
    path: &str,
) -> crp::Result<()> {
    let dim: usize = a.get("dim", 0)?;
    let chunk: usize = a.get("chunk", 1024)?;
    anyhow::ensure!(chunk >= 1, "--chunk must be >= 1");
    let prefix = a.get_str("id-prefix", "row");
    // Chunks ship as they are parsed — the file is never materialized
    // as one Dataset, so peak memory is one --chunk batch no matter
    // how large the input is (the ingest summary reports peak RSS).
    let mut chunks = crp::data::libsvm::LibsvmChunks::open(path, dim, chunk)?;
    let mut client = crp::coordinator::SketchClient::connect_with_retry(addr, 5)?;
    let t0 = std::time::Instant::now();
    let mut rows = 0usize;
    let mut nnz = 0usize;
    let mut cols = 0usize;
    while let Some((csr, _labels)) = chunks.next_chunk()? {
        let n = csr.rows();
        let ids: Vec<String> = (rows..rows + n).map(|r| format!("{prefix}{r}")).collect();
        nnz += csr.nnz();
        cols = cols.max(csr.cols);
        let acked = client.register_sparse_in(collection, ids, csr)?;
        anyhow::ensure!(
            acked as usize == n,
            "short RegisterSparse ack: {acked} of {n}"
        );
        rows += n;
    }
    anyhow::ensure!(rows > 0, "{path}: no rows to register");
    let dt = t0.elapsed().as_secs_f64().max(1e-9);
    let rss = peak_rss_kb()
        .map(|kb| format!("{:.1} MiB", kb as f64 / 1024.0))
        .unwrap_or_else(|| "n/a".into());
    println!(
        "registered {rows} sparse rows ({nnz} nonzeros, d={cols}) from {path} into \
         collection {:?} in {:.2}s  ({:.0} rows/s, {:.0} nnz/s, peak RSS {rss})",
        collection.unwrap_or("default"),
        dt,
        rows as f64 / dt,
        nnz as f64 / dt
    );
    Ok(())
}

/// Peak resident set size of this process in KiB, off /proc (`VmHWM`).
/// `None` where /proc isn't available — the caller prints "n/a".
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_ascii_whitespace().nth(1)?.parse().ok()
}

/// One full `crp stats` page: aggregate counters, the per-request-kind
/// latency table, and the per-collection breakdown. Shared between the
/// one-shot print and the `--watch` refresh loop.
fn print_stats(st: &crp::coordinator::protocol::StatsSnapshot) {
    println!("registered:           {}", st.registered);
    println!("estimates:            {}", st.estimates);
    println!("knn_queries:          {}", st.knn_queries);
    println!("batches_executed:     {}", st.batches_executed);
    println!("vectors_projected:    {}", st.vectors_projected);
    println!("mean_batch_size:      {:.2}", st.mean_batch_size);
    println!("register_us:          p50={} p99={}", st.p50_register_us, st.p99_register_us);
    println!("pending_rows:         {}", st.pending_rows);
    println!("drains:               {}", st.drains);
    println!("tombstones:           {}", st.tombstones);
    println!("kernel:               {}", st.kernel);
    println!("wal_records:          {}", st.wal_records);
    println!("wal_bytes:            {}", st.wal_bytes);
    println!("last_checkpoint_rows: {}", st.last_checkpoint_rows);
    println!("maintenance_wakeups:  {}", st.maintenance_wakeups);
    println!("connections:          {}", st.connections);
    println!("collections:          {}", st.collections);
    if let Some(r) = &st.reactor {
        println!(
            "reactor:              {} polls, {} ready events, {} frames, \
             {} coalesced batches",
            r.polls, r.ready_events, r.frames, r.coalesced_batches
        );
        println!(
            "reactor_dispatch:     p50={} p99={} (per tick); write_hwm={} bytes, \
             batcher_queue={}",
            r.p50_dispatch, r.p99_dispatch, r.write_buffer_hwm, r.batcher_queue_depth
        );
        if r.offloaded_batches > 0 || r.worker_queue_depth > 0 {
            println!(
                "reactor_workers:      {} offloaded batches, {} in flight",
                r.offloaded_batches, r.worker_queue_depth
            );
        }
        for (i, l) in r.per_loop.iter().enumerate() {
            println!(
                "  loop {i}:             {} conns, {} polls, {} ready events, \
                 {} frames, {} coalesced, {} offloaded",
                l.connections, l.polls, l.ready_events, l.frames,
                l.coalesced_batches, l.offloaded_batches
            );
        }
    }
    if let Some(r) = &st.replication {
        println!(
            "replication:          {} of {} (lag {} bytes / {} records, {:.1}s behind, \
             {} bootstrap(s), {} reconnect(s))",
            if r.active { "replica" } else { "promoted primary" },
            r.primary,
            r.lag_bytes,
            r.lag_records,
            r.lag_seconds,
            r.bootstraps,
            r.reconnects
        );
    }
    if !st.per_request.is_empty() {
        println!(
            "\n{:<16} {:>10} {:>12} {:>10} {:>10}",
            "request", "count", "mean_us", "p50_us", "p99_us"
        );
        for r in &st.per_request {
            println!(
                "{:<16} {:>10} {:>12.1} {:>10} {:>10}",
                r.kind, r.count, r.mean_us, r.p50_us, r.p99_us
            );
        }
    }
    if !st.per_collection.is_empty() {
        println!(
            "\n{:<24} {:>10} {:>10} {:>14} {:>14}",
            "collection", "rows", "pending", "wal_bytes", "index_buckets"
        );
        for c in &st.per_collection {
            println!(
                "{:<24} {:>10} {:>10} {:>14} {:>14}",
                c.name, c.rows, c.pending_rows, c.wal_bytes, c.index_buckets
            );
        }
    }
}

/// Scan-engine demo: build a columnar arena of `sketches` synthetic
/// sketches (each `k` coded pseudo-projections), then answer exact
/// top-`top` queries whose projections correlate with a planted base row
/// at `rho` — single queries and one batched fan-out, with throughput.
#[allow(clippy::too_many_arguments)]
fn run_topk_demo(
    sketches: usize,
    k: usize,
    scheme: Scheme,
    w: f64,
    top: usize,
    queries: usize,
    threads: usize,
    rho: f64,
    seed: u64,
) -> crp::Result<()> {
    use crp::mathx::NormalSampler;
    use crp::scan::{scan_topk, scan_topk_batch, CodeArena};

    anyhow::ensure!(queries <= sketches, "--queries must be <= --sketches");
    let params = CodingParams::new(scheme, w);
    let bits = params.bits_per_code();
    let mut arena = CodeArena::new(k, bits);
    let mut ns = NormalSampler::new(seed, 2);
    let mut buf = vec![0f32; k];
    // Queries correlate with base rows 0..queries, so keep those raw.
    let mut base_vals: Vec<Vec<f32>> = Vec::with_capacity(queries);
    let t_build = std::time::Instant::now();
    for i in 0..sketches {
        ns.fill_f32(&mut buf);
        arena.insert(&format!("{i:07}"), &crp::coding::pack_codes(&params.encode(&buf), bits));
        if i < queries {
            base_vals.push(buf.clone());
        }
    }
    eprintln!(
        "arena: {} sketches x {} codes @ {} bit(s) = {:.1} MiB, built in {:.2}s \
         (kernel: {})",
        sketches,
        k,
        arena.bits(),
        arena.storage_bytes() as f64 / (1 << 20) as f64,
        t_build.elapsed().as_secs_f64(),
        crp::scan::CollisionKernel::select(arena.bits()).kind().label()
    );

    let c = (1.0 - rho * rho).sqrt();
    let packed_queries: Vec<_> = base_vals
        .iter()
        .map(|base| {
            let q: Vec<f32> = base
                .iter()
                .map(|&x| (rho * x as f64 + c * ns.next()) as f32)
                .collect();
            crp::coding::pack_codes(&params.encode(&q), bits)
        })
        .collect();

    let est = crp::estimator::CollisionEstimator::new(params);
    let mut top1_hits = 0usize;
    let t_scan = std::time::Instant::now();
    for (j, q) in packed_queries.iter().enumerate() {
        let hits = scan_topk(&arena, q, top, threads);
        if let Some(first) = hits.first() {
            if first.id == format!("{j:07}") {
                top1_hits += 1;
            }
            if j == 0 {
                println!("{:<10} {:>10} {:>10}", "id", "collisions", "rho_hat");
                for h in &hits {
                    println!(
                        "{:<10} {:>10} {:>10.4}",
                        h.id,
                        h.collisions,
                        est.estimate_from_count(h.collisions, k)
                    );
                }
            }
        }
    }
    let serial = t_scan.elapsed().as_secs_f64();
    let t_batch = std::time::Instant::now();
    let batched = scan_topk_batch(&arena, &packed_queries, top, threads);
    let batch = t_batch.elapsed().as_secs_f64();
    anyhow::ensure!(batched.len() == packed_queries.len(), "batch result count");
    println!(
        "\n{} queries over {} sketches: top-1 recall of planted base = {:.2}",
        queries,
        sketches,
        top1_hits as f64 / queries.max(1) as f64
    );
    println!(
        "query-at-a-time: {:>10.2} ms/query  {:>14.0} sketches/s",
        1e3 * serial / queries.max(1) as f64,
        sketches as f64 * queries as f64 / serial
    );
    println!(
        "batched fan-out: {:>10.2} ms/query  {:>14.0} sketches/s",
        1e3 * batch / queries.max(1) as f64,
        sketches as f64 * queries as f64 / batch
    );
    Ok(())
}

/// Remote top-k: send `queries` random query vectors to a running
/// server (optionally namespaced to a collection) and print the hits.
/// With `approx`, the batch goes through `ApproxTopK` instead.
#[allow(clippy::too_many_arguments)]
fn run_topk_remote(
    addr: &str,
    collection: Option<&str>,
    dim: usize,
    top: usize,
    queries: usize,
    seed: u64,
    approx: bool,
    probes: u32,
) -> crp::Result<()> {
    use crp::mathx::NormalSampler;
    let mut client = crp::coordinator::SketchClient::connect_with_retry(addr, 5)?;
    let mut ns = NormalSampler::new(seed, 3);
    let vectors: Vec<Vec<f32>> = (0..queries.max(1))
        .map(|_| (0..dim).map(|_| ns.next() as f32).collect())
        .collect();
    let t0 = std::time::Instant::now();
    let results = if approx {
        client.approx_topk_in(collection, vectors, top as u32, probes)?
    } else {
        client.topk_in(collection, vectors, top as u32)?
    };
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "collection {:?}: {} {} queries x top-{top} in {:.1} ms",
        collection.unwrap_or("default"),
        results.len(),
        if approx { "approx" } else { "exact" },
        1e3 * dt
    );
    if let Some(hits) = results.first() {
        println!("{:<24} {:>10}", "id", "rho_hat");
        for h in hits {
            println!("{:<24} {:>10.4}", h.id, h.rho);
        }
    }
    Ok(())
}

/// Banded-index demo: a corpus with planted ρ-neighbors, exact top-k as
/// the oracle, `ApproxTopK`-style index scans against it — recall@top
/// and the speedup, on one machine, no server.
#[allow(clippy::too_many_arguments)]
fn run_topk_approx_demo(
    sketches: usize,
    k: usize,
    scheme: Scheme,
    w: f64,
    top: usize,
    queries: usize,
    rho: f64,
    probes: usize,
    seed: u64,
) -> crp::Result<()> {
    use crp::lsh::IndexConfig;
    use crp::scan::{EpochArena, EpochConfig};

    anyhow::ensure!(queries >= 1 && top >= 1, "--queries and --top must be >= 1");
    let params = CodingParams::new(scheme, w);
    let bits = params.bits_per_code();
    let icfg = IndexConfig::for_shape(k, bits);
    let probes = if probes == 0 { icfg.probes } else { probes };
    let arena = EpochArena::with_index_config(k, bits, EpochConfig::default(), icfg);
    // Each query's base gets `top + 2` ρ-correlated neighbors planted
    // in the corpus, so the exact top-`top` is dominated by true
    // neighbors the index must find.
    let planted_per_query = top + 2;
    anyhow::ensure!(
        queries * planted_per_query <= sketches,
        "--queries x (top + 2) planted rows exceed --sketches"
    );
    let t_build = std::time::Instant::now();
    let (rows, packed_queries) = crp::data::planted_code_corpus(
        &params,
        k,
        sketches,
        queries,
        planted_per_query,
        rho,
        seed,
    );
    for (i, r) in rows.iter().enumerate() {
        let _ = arena.put(&format!("{i:07}"), r);
    }
    arena.drain();
    eprintln!(
        "arena: {} sketches x {k} codes @ {} bit(s), {} index buckets, built in {:.2}s \
         (bands={}, band_bits={}, probes={probes}, kernel={})",
        rows.len(),
        arena.bits(),
        arena.index_buckets(),
        t_build.elapsed().as_secs_f64(),
        icfg.bands,
        icfg.band_bits,
        arena.kernel_kind().label()
    );

    let t_exact = std::time::Instant::now();
    let exact: Vec<_> = packed_queries
        .iter()
        .map(|q| arena.scan_topk(q, top, 0))
        .collect();
    let exact_s = t_exact.elapsed().as_secs_f64();
    let t_approx = std::time::Instant::now();
    let approx: Vec<_> = packed_queries
        .iter()
        .map(|q| arena.scan_topk_approx(q, top, probes))
        .collect();
    let approx_s = t_approx.elapsed().as_secs_f64();

    let mut found = 0usize;
    let mut wanted = 0usize;
    for (e, ap) in exact.iter().zip(&approx) {
        wanted += e.len();
        for hit in e {
            if ap.iter().any(|h| h.id == hit.id) {
                found += 1;
            }
        }
    }
    println!(
        "recall@{top} vs exact oracle: {:.3}  ({} queries, rho={rho})",
        found as f64 / wanted.max(1) as f64,
        queries
    );
    println!(
        "exact : {:>10.2} ms/query  {:>14.0} sketches/s",
        1e3 * exact_s / queries as f64,
        rows.len() as f64 * queries as f64 / exact_s
    );
    println!(
        "approx: {:>10.2} ms/query  {:>14.0} sketches/s-equivalent  ({:.1}x)",
        1e3 * approx_s / queries as f64,
        rows.len() as f64 * queries as f64 / approx_s,
        exact_s / approx_s
    );
    Ok(())
}

/// Post-ingest query phase of `bench-serve`: send `queries` random
/// vectors in frames of up to 16 and report query throughput.
fn bench_queries(
    addr: &str,
    collection: Option<&str>,
    queries: usize,
    dim: usize,
    top: u32,
    approx: bool,
    probes: u32,
) -> crp::Result<()> {
    use crp::mathx::NormalSampler;
    let mut client = crp::coordinator::SketchClient::connect_with_retry(addr, 5)?;
    let mut ns = NormalSampler::new(777, 5);
    let t0 = std::time::Instant::now();
    let mut sent = 0usize;
    while sent < queries {
        let batch = (queries - sent).min(16);
        let vectors: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..dim).map(|_| ns.next() as f32).collect())
            .collect();
        let results = if approx {
            client.approx_topk_in(collection, vectors, top, probes)?
        } else {
            client.topk_in(collection, vectors, top)?
        };
        anyhow::ensure!(results.len() == batch, "short TopK response");
        sent += batch;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} {} top-{top} queries in {:.2}s  ({:.0} queries/s)",
        sent,
        if approx { "approx" } else { "exact" },
        dt,
        sent as f64 / dt
    );
    Ok(())
}

/// Closed-loop load generator: register `n` vectors across `connections`
/// concurrent clients (optionally into a named collection), then report
/// latency percentiles.
fn bench_serve(
    addr: &str,
    n: usize,
    dim: usize,
    connections: usize,
    collection: Option<String>,
) -> crp::Result<()> {
    use crp::coordinator::SketchClient;
    use crp::mathx::NormalSampler;
    let t0 = std::time::Instant::now();
    let per = n / connections.max(1);
    let mut handles = Vec::new();
    for c in 0..connections {
        let addr = addr.to_string();
        let collection = collection.clone();
        handles.push(std::thread::spawn(move || -> crp::Result<Vec<u64>> {
            let mut client = SketchClient::connect_with_retry(&addr, 5)?;
            let mut ns = NormalSampler::new(c as u64, 1);
            let mut lat_us: Vec<u64> = Vec::with_capacity(per);
            for i in 0..per {
                let v: Vec<f32> = (0..dim).map(|_| ns.next() as f32).collect();
                let t = std::time::Instant::now();
                client.register_in(collection.as_deref(), &format!("c{c}-{i}"), v)?;
                lat_us.push(t.elapsed().as_micros() as u64);
            }
            Ok(lat_us)
        }));
    }
    let mut all: Vec<u64> = Vec::new();
    for h in handles {
        all.extend(h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
    }
    anyhow::ensure!(!all.is_empty(), "no requests completed");
    all.sort_unstable();
    let total = t0.elapsed().as_secs_f64();
    let pct = |p: f64| all[((all.len() as f64 - 1.0) * p) as usize];
    println!(
        "registered {} vectors in {:.2}s  ({:.0} req/s)",
        all.len(),
        total,
        all.len() as f64 / total
    );
    println!(
        "latency us: p50={} p90={} p99={} max={}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        all.last().unwrap()
    );
    Ok(())
}
