//! Asymptotic variance factors `V` of the collision-inversion estimators:
//! `Var(ρ̂) = V/k + O(1/k²)` for `k` projections (Theorems 2–4, Eq. 20).
//!
//! Each `V` is `P(1−P) / (∂P/∂ρ)²` by the delta method; the paper gives
//! the `∂P/∂ρ` in closed form (Appendices B–D) and we implement those
//! forms directly, with series truncation matched to `collision.rs`.

use super::collision::{p_1, p_w, p_w2, p_wq};
use crate::mathx::{phi_pdf, PHI0};

const TAIL: f64 = 9.0;
const PI: f64 = std::f64::consts::PI;

/// `∂P_w/∂ρ` — Appendix C:
///
/// ```text
/// (1/π) (1−ρ²)^{-1/2} Σ_{i≥0} ( e^{-(i+1)²w²/(1+ρ)} + e^{-i²w²/(1+ρ)}
///                               − 2 e^{-w²/(2(1−ρ²))} e^{-i(i+1)w²/(1+ρ)} )
/// ```
pub fn dp_drho_w(rho: f64, w: f64) -> f64 {
    let rho = rho.min(1.0 - 1e-12);
    let one_m_r2 = 1.0 - rho * rho;
    let imax = (TAIL / w).ceil().max(4.0) as usize + 2;
    let cross = (-w * w / (2.0 * one_m_r2)).exp();
    let mut sum = 0.0;
    for i in 0..=imax {
        let i = i as f64;
        let term = (-(i + 1.0) * (i + 1.0) * w * w / (1.0 + rho)).exp()
            + (-i * i * w * w / (1.0 + rho)).exp()
            - 2.0 * cross * (-i * (i + 1.0) * w * w / (1.0 + rho)).exp();
        sum += term;
        if i * w > TAIL {
            break;
        }
    }
    sum / (PI * one_m_r2.sqrt())
}

/// `V_w(ρ, w)` — Theorem 3, Eq. (15).
pub fn v_w(rho: f64, w: f64) -> f64 {
    let p = p_w(rho, w);
    let dp = dp_drho_w(rho, w);
    p * (1.0 - p) / (dp * dp)
}

/// `V_{w,q}(ρ, w)` — Theorem 2, Eq. (13):
///
/// ```text
/// V_{w,q} = (d²/4) ( t / (φ(t) − 1/√(2π)) )² P_{w,q}(1−P_{w,q}),  t = w/√d
/// ```
pub fn v_wq(rho: f64, w: f64) -> f64 {
    let d = 2.0 * (1.0 - rho);
    let t = w / d.sqrt();
    let p = p_wq(rho, w);
    let denom = phi_pdf(t) - PHI0;
    let g = t / denom;
    d * d / 4.0 * g * g * p * (1.0 - p)
}

/// `V_{w,q}` expressed against the scale-free variable `t = w/√d`, with
/// the `d²/4` factor removed — exactly what the paper plots in Figure 2.
/// Its minimum is `7.6797` at `t = 1.6476`.
pub fn v_wq_scale_free(t: f64) -> f64 {
    // P_{w,q} depends on (ρ, w) only through t.
    let p = {
        use crate::mathx::{phi_cdf, SQRT_2PI};
        (2.0 * phi_cdf(t) - 1.0 - 2.0 / (SQRT_2PI * t) + 2.0 / t * phi_pdf(t)).clamp(0.0, 1.0)
    };
    let denom = phi_pdf(t) - PHI0;
    let g = t / denom;
    g * g * p * (1.0 - p)
}

/// `∂P_{w,2}/∂ρ` — Appendix D:
///
/// ```text
/// (1/π)(1−ρ²)^{-1/2} [ 1 − 2 e^{-w²/(2(1−ρ²))} + 2 e^{-w²/(1+ρ)} ]
/// ```
pub fn dp_drho_w2(rho: f64, w: f64) -> f64 {
    let rho = rho.min(1.0 - 1e-12);
    let one_m_r2 = 1.0 - rho * rho;
    (1.0 - 2.0 * (-w * w / (2.0 * one_m_r2)).exp() + 2.0 * (-w * w / (1.0 + rho)).exp())
        / (PI * one_m_r2.sqrt())
}

/// `V_{w,2}(ρ, w)` — Theorem 4, Eq. (18).
pub fn v_w2(rho: f64, w: f64) -> f64 {
    let p = p_w2(rho, w);
    let one_m_r2 = 1.0 - rho * rho;
    let bracket =
        1.0 - 2.0 * (-w * w / (2.0 * one_m_r2)).exp() + 2.0 * (-w * w / (1.0 + rho)).exp();
    PI * PI * one_m_r2 * p * (1.0 - p) / (bracket * bracket)
}

/// `V_1(ρ) = π²(1−ρ²) P_1(1−P_1)` — Eq. (20).
pub fn v_1(rho: f64) -> f64 {
    let p = p_1(rho);
    PI * PI * (1.0 - rho * rho) * p * (1.0 - p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mathx::{grid_then_golden_min, phi_cdf};

    #[test]
    fn fig2_minimum_constant() {
        // Paper: min of V_{w,q}·4/d² is 7.6797 at w/√d = 1.6476.
        let (t, v) = grid_then_golden_min(v_wq_scale_free, 0.2, 6.0, 300, false, 1e-10);
        assert!((t - 1.6476).abs() < 5e-4, "argmin t = {t}");
        assert!((v - 7.6797).abs() < 5e-4, "min = {v}");
    }

    #[test]
    fn vw_rho0_limit_pi2_over_4() {
        // Theorem 3 remark: V_w|ρ=0 → π²/4 = 2.4674 as w → ∞.
        let v = v_w(0.0, 30.0);
        assert!(
            (v - std::f64::consts::PI.powi(2) / 4.0).abs() < 1e-6,
            "V_w(0, 30) = {v}"
        );
    }

    #[test]
    fn vw_rho0_closed_form_eq16() {
        // Eq. (16): explicit ratio form at ρ = 0.
        for &w in &[0.5, 1.0, 2.0, 4.0] {
            let num: f64 = (0..200)
                .map(|i| {
                    let a = phi_cdf((i + 1) as f64 * w) - phi_cdf(i as f64 * w);
                    a * a
                })
                .sum();
            let den: f64 = (0..200)
                .map(|i| {
                    let a = phi_pdf((i + 1) as f64 * w) - phi_pdf(i as f64 * w);
                    a * a
                })
                .sum();
            let want = num * (0.5 - num) / (den * den);
            let got = v_w(0.0, w);
            assert!(
                ((got - want) / want).abs() < 1e-6,
                "w={w}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn v1_reference_values() {
        // V_1(0) = π² · 1 · 1/2 · 1/2 = π²/4.
        assert!((v_1(0.0) - std::f64::consts::PI.powi(2) / 4.0).abs() < 1e-12);
        // V_1 → 0 as ρ → 1.
        assert!(v_1(0.9999) < 1e-2);
    }

    #[test]
    fn vwq_at_rho0_bigger_than_vw_limit() {
        // The remark after Theorem 3: optimized V_{w,q}(ρ=0) = 7.6797 vs
        // V_w's π²/4 = 2.4674 — our scheme is ~3.1× more accurate there.
        let (_, vwq_best) = grid_then_golden_min(|w| v_wq(0.0, w), 0.2, 12.0, 300, false, 1e-9);
        assert!((vwq_best - 7.6797).abs() < 5e-4, "{vwq_best}");
        assert!(vwq_best / (std::f64::consts::PI.powi(2) / 4.0) > 3.0);
    }

    #[test]
    fn dp_w_matches_numeric() {
        for &(rho, w) in &[(0.1, 0.5), (0.5, 1.0), (0.8, 2.0), (0.0, 0.75)] {
            let h = 1e-5;
            // Symmetric difference inside the domain, forward at ρ = 0.
            let num = if rho >= h {
                (p_w(rho + h, w) - p_w(rho - h, w)) / (2.0 * h)
            } else {
                (p_w(rho + h, w) - p_w(rho, w)) / h
            };
            let ana = dp_drho_w(rho, w);
            assert!(
                ((num - ana) / ana).abs() < 1e-3,
                "rho={rho} w={w}: {num} vs {ana}"
            );
        }
    }

    #[test]
    fn dp_w2_matches_numeric() {
        for &(rho, w) in &[(0.1, 0.75), (0.5, 0.75), (0.8, 1.5), (0.3, 0.25)] {
            let h = 1e-5;
            let num = (p_w2(rho + h, w) - p_w2(rho - h, w)) / (2.0 * h);
            let ana = dp_drho_w2(rho, w);
            assert!(
                ((num - ana) / ana).abs() < 1e-4,
                "rho={rho} w={w}: {num} vs {ana}"
            );
        }
    }

    #[test]
    fn vw2_limits_equal_v1() {
        // h_{w,2} degenerates to the sign scheme at w = 0 and w = ∞.
        for &rho in &[0.1, 0.5, 0.9] {
            let v0 = v_w2(rho, 1e-9);
            let vinf = v_w2(rho, 40.0);
            let v1 = v_1(rho);
            assert!(((v0 - v1) / v1).abs() < 1e-5, "rho={rho} w→0: {v0} vs {v1}");
            assert!(
                ((vinf - v1) / v1).abs() < 1e-5,
                "rho={rho} w→∞: {vinf} vs {v1}"
            );
        }
    }

    #[test]
    fn fig4_shape_vw_beats_vwq_for_large_w() {
        // Figure 4: V_w < V_{w,q} especially when w > 2.
        for &rho in &[0.0, 0.25, 0.5, 0.75] {
            for &w in &[2.5, 4.0, 6.0] {
                assert!(
                    v_w(rho, w) < v_wq(rho, w),
                    "rho={rho} w={w}: V_w={} V_wq={}",
                    v_w(rho, w),
                    v_wq(rho, w)
                );
            }
        }
    }

    #[test]
    fn fig7_shape_vw2_beats_vw_small_w_low_rho() {
        // Figure 7: for ρ ≤ 0.5 and small w, V_{w,2} ≪ V_w; at high ρ
        // V_{w,2} is somewhat higher.
        assert!(v_w2(0.25, 0.3) < v_w(0.25, 0.3));
        assert!(v_w2(0.5, 0.3) < v_w(0.5, 0.3));
        assert!(v_w2(0.95, 0.75) > v_w(0.95, 0.75) * 0.8);
    }

    #[test]
    fn variance_positive_finite() {
        for scheme in crate::theory::SchemeKind::ALL {
            for &rho in &[0.0, 0.3, 0.6, 0.9, 0.99] {
                for &w in &[0.25, 0.75, 1.5, 4.0] {
                    let v = scheme.variance_factor(rho, w);
                    assert!(v.is_finite() && v >= 0.0, "{scheme:?} rho={rho} w={w}: {v}");
                }
            }
        }
    }
}
