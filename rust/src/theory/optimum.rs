//! Per-ρ optimum bin width: `w*(ρ) = argmin_w V(w; ρ)` for each scheme
//! (Figures 5, 8, and the max-over-w ratios of Figure 9).
//!
//! The search range is capped at `W_MAX = 20`: the paper observes that
//! for `h_w` the optimum exceeds 6 once `ρ < 0.56` ("may not be reliably
//! evaluated") and tends to ∞ at ρ = 0; we report the cap in that regime,
//! which is what the paper's Figure 5 (right) effectively does.

use super::variance::{v_w, v_w2, v_wq};
use super::SchemeKind;
use crate::mathx::grid_then_golden_min;

/// Upper end of the w search range. `w > 6` already means "1 bit
/// suffices" (normal tail beyond 6 is 9.9e-10), so the cap only affects
/// the regime the paper itself flags as degenerate.
pub const W_MAX: f64 = 20.0;
/// Lower end of the w search range.
pub const W_MIN: f64 = 0.05;

/// Result of an optimum-w search.
#[derive(Clone, Copy, Debug)]
pub struct OptimumResult {
    /// The minimizing bin width (clamped to `[W_MIN, W_MAX]`).
    pub w: f64,
    /// The variance factor at the optimum.
    pub v: f64,
    /// True when the optimizer ran into the `W_MAX` cap (the ρ < 0.56
    /// regime for `h_w` where the true optimum diverges).
    pub at_cap: bool,
}

/// `argmin_w V(w; ρ)` for the given scheme. For [`SchemeKind::OneBit`]
/// there is no w; returns `V_1(ρ)` with `w = 0`.
pub fn optimum_w(scheme: SchemeKind, rho: f64) -> OptimumResult {
    let f: Box<dyn Fn(f64) -> f64> = match scheme {
        SchemeKind::Uniform => Box::new(move |w| v_w(rho, w)),
        SchemeKind::WindowOffset => Box::new(move |w| v_wq(rho, w)),
        SchemeKind::TwoBit => Box::new(move |w| v_w2(rho, w)),
        SchemeKind::OneBit => {
            return OptimumResult {
                w: 0.0,
                v: super::variance::v_1(rho),
                at_cap: false,
            }
        }
    };
    let (w, v) = grid_then_golden_min(&*f, W_MIN, W_MAX, 400, false, 1e-8);
    // The variance curves flatten to machine precision well before W_MAX
    // in the diverging-optimum regime (paper: ρ < 0.56 for h_w, where the
    // true argmin is ∞). If the curve is flat between the grid argmin and
    // the cap, report the cap — that is the paper's reading of "optimum
    // w is very large / unreliable to evaluate".
    let v_cap = f(W_MAX);
    if v_cap <= v * (1.0 + 1e-9) {
        OptimumResult {
            w: W_MAX,
            v: v_cap,
            at_cap: true,
        }
    } else {
        OptimumResult {
            w,
            v,
            at_cap: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::variance::v_1;

    #[test]
    fn fig5_optimized_vw_below_vwq_low_rho() {
        // Figure 5 left: optimized V_w significantly below optimized
        // V_{w,q} for ρ < 0.56.
        for &rho in &[0.0, 0.1, 0.25, 0.4, 0.5] {
            let vw = optimum_w(SchemeKind::Uniform, rho).v;
            let vwq = optimum_w(SchemeKind::WindowOffset, rho).v;
            assert!(
                vw < vwq,
                "rho={rho}: V_w*={vw} not below V_wq*={vwq}"
            );
        }
    }

    #[test]
    fn fig5_optimum_w_divergence_low_rho() {
        // Figure 5 right: for ρ < 0.56 the h_w optimum w exceeds 6 (we
        // report the cap); the h_{w,q} optimum stays small (≈ 1–3).
        let r = optimum_w(SchemeKind::Uniform, 0.3);
        assert!(r.w > 6.0, "h_w optimum at rho=0.3 is {}", r.w);
        let r0 = optimum_w(SchemeKind::Uniform, 0.0);
        assert!(r0.at_cap, "h_w optimum at rho=0 should hit the cap");
        let rq = optimum_w(SchemeKind::WindowOffset, 0.0);
        assert!(
            rq.w > 1.0 && rq.w < 4.0,
            "h_wq optimum at rho=0 is {} (paper: ≈ 2)",
            rq.w
        );
    }

    #[test]
    fn fig5_high_rho_small_w() {
        // For high ρ the h_w optimum becomes small (w < 1 region).
        let r = optimum_w(SchemeKind::Uniform, 0.95);
        assert!(r.w < 1.5, "h_w optimum at rho=0.95 is {}", r.w);
    }

    #[test]
    fn fig8_vw2_close_to_vw() {
        // Figure 8 left: minimized V_{w,2} tracks minimized V_w closely,
        // with h_w slightly better at high ρ.
        for &rho in &[0.1, 0.3, 0.5, 0.7, 0.9] {
            let vw = optimum_w(SchemeKind::Uniform, rho).v;
            let vw2 = optimum_w(SchemeKind::TwoBit, rho).v;
            let ratio = vw2 / vw;
            assert!(
                (0.8..2.0).contains(&ratio),
                "rho={rho}: ratio {ratio} (V_w2*={vw2}, V_w*={vw})"
            );
        }
        let hi = 0.95;
        assert!(optimum_w(SchemeKind::Uniform, hi).v <= optimum_w(SchemeKind::TwoBit, hi).v);
    }

    #[test]
    fn fig9_one_bit_loses_at_high_rho() {
        // Figure 9: Var(ρ̂_1)/Var(ρ̂_w) grows large as ρ → 1.
        for &rho in &[0.9, 0.95, 0.99] {
            let ratio = v_1(rho) / optimum_w(SchemeKind::Uniform, rho).v;
            assert!(ratio > 1.5, "rho={rho}: ratio {ratio}");
        }
        // ...but at ρ = 0 the 1-bit scheme is already optimal for h_w
        // (w → ∞ limit IS the sign scheme): ratio → 1.
        let r0 = v_1(0.0) / optimum_w(SchemeKind::Uniform, 0.0).v;
        assert!((r0 - 1.0).abs() < 0.02, "rho=0 ratio {r0}");
    }

    #[test]
    fn one_bit_passthrough() {
        let r = optimum_w(SchemeKind::OneBit, 0.5);
        assert_eq!(r.w, 0.0);
        assert!((r.v - v_1(0.5)).abs() < 1e-12);
    }
}
