//! The paper's analysis, implemented exactly.
//!
//! * [`collision`] — collision probabilities: `P_w` (Theorem 1), `P_{w,q}`
//!   (Eq. 7, Datar et al.), `P_{w,2}` (Theorem 4), `P_1` (Eq. 19).
//! * [`variance`] — asymptotic variance factors of the collision-inversion
//!   estimators: `V_w` (Theorem 3), `V_{w,q}` (Theorem 2), `V_{w,2}`
//!   (Theorem 4), `V_1` (Eq. 20), plus the `∂P/∂ρ` derivatives they are
//!   built from (Lemma 1 / Appendices B–D).
//! * [`optimum`] — per-ρ optimum bin width `argmin_w V(w; ρ)` for each
//!   scheme (Figures 5, 8, 9).
//! * [`invert`] — monotone ρ ↔ P inversion (tables + on-demand bisection),
//!   the estimator backend.

pub mod collision;
pub mod variance;
pub mod optimum;
pub mod invert;
pub mod nonuniform;

pub use collision::{p_1, p_w, p_w2, p_wq, q_interval};
pub use nonuniform::NonUniformScheme;
pub use invert::{InversionTable, rho_from_p};
pub use optimum::{optimum_w, OptimumResult};
pub use variance::{dp_drho_w, dp_drho_w2, v_1, v_w, v_w2, v_wq};

/// The four coding schemes analyzed in the paper. Carried through the
/// theory, estimator, figure, and serving layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// `h_w` — uniform quantization `floor(x/w)` (proposed, Section 1.1).
    Uniform,
    /// `h_{w,q}` — window + random offset `floor((x+q)/w)` (Datar et al.).
    WindowOffset,
    /// `h_{w,2}` — non-uniform 2-bit over `(-∞,-w),[-w,0),[0,w),[w,∞)`.
    TwoBit,
    /// `h_1` — 1-bit sign coding.
    OneBit,
}

impl SchemeKind {
    pub const ALL: [SchemeKind; 4] = [
        SchemeKind::Uniform,
        SchemeKind::WindowOffset,
        SchemeKind::TwoBit,
        SchemeKind::OneBit,
    ];

    /// Collision probability of this scheme at similarity `rho`, bin
    /// width `w` (ignored for `OneBit`).
    pub fn collision_probability(self, rho: f64, w: f64) -> f64 {
        match self {
            SchemeKind::Uniform => p_w(rho, w),
            SchemeKind::WindowOffset => p_wq(rho, w),
            SchemeKind::TwoBit => p_w2(rho, w),
            SchemeKind::OneBit => p_1(rho),
        }
    }

    /// Asymptotic variance factor `V` such that
    /// `Var(ρ̂) = V/k + O(1/k²)`.
    pub fn variance_factor(self, rho: f64, w: f64) -> f64 {
        match self {
            SchemeKind::Uniform => v_w(rho, w),
            SchemeKind::WindowOffset => v_wq(rho, w),
            SchemeKind::TwoBit => v_w2(rho, w),
            SchemeKind::OneBit => v_1(rho),
        }
    }

    /// Paper-style display name.
    pub fn label(self) -> &'static str {
        match self {
            SchemeKind::Uniform => "h_w",
            SchemeKind::WindowOffset => "h_wq",
            SchemeKind::TwoBit => "h_w2",
            SchemeKind::OneBit => "h_1",
        }
    }

    /// Stable one-byte encoding used by the wire protocol and the
    /// collection MANIFEST. Never renumber: these values are persisted.
    pub fn wire_code(self) -> u8 {
        match self {
            SchemeKind::Uniform => 0,
            SchemeKind::WindowOffset => 1,
            SchemeKind::TwoBit => 2,
            SchemeKind::OneBit => 3,
        }
    }

    /// Inverse of [`SchemeKind::wire_code`].
    pub fn from_wire_code(code: u8) -> Option<SchemeKind> {
        match code {
            0 => Some(SchemeKind::Uniform),
            1 => Some(SchemeKind::WindowOffset),
            2 => Some(SchemeKind::TwoBit),
            3 => Some(SchemeKind::OneBit),
            _ => None,
        }
    }
}
