//! Generalized non-uniform coding — the extension the paper's Section 4
//! sets up: `h_{w,2}` partitions R into 4 regions at `{-w, 0, w}`; here
//! we allow any symmetric boundary set `0 < w_1 < … < w_m`, giving
//! `2(m+1)` regions (`b = log2(2m+2)` bits). `m = 1` recovers `h_{w,2}`
//! exactly; larger `m` interpolates toward uniform quantization while
//! keeping the paper's "spend resolution where the density is" design.
//!
//! Collision probabilities come from bivariate-normal rectangle masses
//! (Lemma 1's generalization), `∂P/∂ρ` numerically, and the variance
//! factor by the same delta-method as Theorems 2–4. A coordinate-descent
//! optimizer finds boundaries minimizing the variance at a target ρ.

use crate::mathx::normal::bvn_rect;
use crate::mathx::golden_section_min;

/// A symmetric non-uniform scheme with regions split at `±boundaries`
/// (sorted ascending) and at 0.
#[derive(Clone, Debug)]
pub struct NonUniformScheme {
    boundaries: Vec<f64>,
}

impl NonUniformScheme {
    pub fn new(mut boundaries: Vec<f64>) -> Self {
        assert!(!boundaries.is_empty());
        boundaries.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(boundaries[0] > 0.0, "boundaries must be positive");
        NonUniformScheme { boundaries }
    }

    /// The paper's `h_{w,2}` as the m = 1 special case.
    pub fn two_bit(w: f64) -> Self {
        Self::new(vec![w])
    }

    pub fn boundaries(&self) -> &[f64] {
        &self.boundaries
    }

    /// Number of regions: `2(m + 1)`.
    pub fn cardinality(&self) -> usize {
        2 * (self.boundaries.len() + 1)
    }

    /// Bits per code.
    pub fn bits_per_code(&self) -> u32 {
        (usize::BITS - (self.cardinality() - 1).leading_zeros()).max(1)
    }

    /// Region edges, ascending, including ±∞ and 0:
    /// `[-∞, -w_m, …, -w_1, 0, w_1, …, w_m, +∞]`.
    fn edges(&self) -> Vec<f64> {
        let m = self.boundaries.len();
        let mut e = Vec::with_capacity(2 * m + 3);
        e.push(f64::NEG_INFINITY);
        for &w in self.boundaries.iter().rev() {
            e.push(-w);
        }
        e.push(0.0);
        e.extend(self.boundaries.iter().copied());
        e.push(f64::INFINITY);
        e
    }

    /// Encode one value to its region index (0-based from the left).
    pub fn encode_one(&self, x: f64) -> u16 {
        let edges = self.edges();
        // Regions are [e_i, e_{i+1}); linear scan (m is tiny).
        for i in 1..edges.len() {
            if x < edges[i] {
                return (i - 1) as u16;
            }
        }
        (edges.len() - 2) as u16
    }

    /// Encode a slice of projected values.
    pub fn encode(&self, xs: &[f32]) -> Vec<u16> {
        xs.iter().map(|&x| self.encode_one(x as f64)).collect()
    }

    /// Collision probability `P(ρ) = Σ_regions Pr(x, y both in region)`.
    pub fn collision_probability(&self, rho: f64) -> f64 {
        let edges = self.edges();
        let mut p = 0.0;
        for i in 0..edges.len() - 1 {
            p += bvn_rect(edges[i], edges[i + 1], edges[i], edges[i + 1], rho);
        }
        p.clamp(0.0, 1.0)
    }

    /// `∂P/∂ρ` by central difference (the closed form exists via
    /// Lemma 1's Eq. 9 summed over regions; numeric keeps this generic).
    pub fn dp_drho(&self, rho: f64) -> f64 {
        let h = 1e-5;
        let lo = (rho - h).max(0.0);
        let hi = (rho + h).min(1.0 - 1e-9);
        (self.collision_probability(hi) - self.collision_probability(lo)) / (hi - lo)
    }

    /// Delta-method variance factor `V = P(1−P)/(∂P/∂ρ)²`.
    pub fn variance_factor(&self, rho: f64) -> f64 {
        let p = self.collision_probability(rho);
        let dp = self.dp_drho(rho);
        p * (1.0 - p) / (dp * dp)
    }

    /// Optimize the boundaries for a target ρ by cyclic coordinate
    /// descent (each boundary minimized by golden-section within its
    /// neighbors' bracket). Returns the optimized scheme and its V.
    pub fn optimize_for(m: usize, rho: f64) -> (Self, f64) {
        assert!(m >= 1 && m <= 4, "supported m: 1..=4");
        // Initialize: equally spaced quantiles of |N(0,1)| up to ~2.
        let mut b: Vec<f64> = (1..=m).map(|i| i as f64 * 2.0 / (m as f64 + 0.5)).collect();
        let mut best_v = NonUniformScheme::new(b.clone()).variance_factor(rho);
        for _sweep in 0..6 {
            let mut improved = false;
            for i in 0..m {
                let lo = if i == 0 { 0.02 } else { b[i - 1] + 0.02 };
                let hi = if i + 1 < m { b[i + 1] - 0.02 } else { 8.0 };
                if hi <= lo {
                    continue;
                }
                let b_clone = b.clone();
                let (x, v) = golden_section_min(
                    |w| {
                        let mut cand = b_clone.clone();
                        cand[i] = w;
                        NonUniformScheme::new(cand).variance_factor(rho)
                    },
                    lo,
                    hi,
                    1e-5,
                );
                if v < best_v - 1e-12 {
                    best_v = v;
                    b[i] = x;
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
        (NonUniformScheme::new(b), best_v)
    }
}

/// Bit-budget ablation row: the best variance factor achievable per
/// scheme family at a given ρ, alongside the bits spent.
pub fn bit_budget_table(rho: f64) -> Vec<(String, u32, f64)> {
    use super::optimum::optimum_w;
    use super::variance::v_1;
    use super::SchemeKind;
    let mut rows = Vec::new();
    rows.push(("h_1 (1 bit)".to_string(), 1, v_1(rho)));
    let (s2, v2) = NonUniformScheme::optimize_for(1, rho);
    rows.push((
        format!("h_w2* (2 bit, w={:.3})", s2.boundaries()[0]),
        2,
        v2,
    ));
    let (s3, v3) = NonUniformScheme::optimize_for(3, rho);
    rows.push((
        format!(
            "nonuniform-3bit* (w={:.2},{:.2},{:.2})",
            s3.boundaries()[0],
            s3.boundaries()[1],
            s3.boundaries()[2]
        ),
        3,
        v3,
    ));
    let rw = optimum_w(SchemeKind::Uniform, rho);
    let bits = crate::coding::CodingParams::new(crate::coding::Scheme::Uniform, rw.w)
        .bits_per_code();
    rows.push((format!("h_w* (w={:.2})", rw.w), bits, rw.v));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::{p_w2, v_w2};

    #[test]
    fn m1_matches_two_bit_theory() {
        // The generalized machinery must reproduce Theorem 4 exactly.
        for &(rho, w) in &[(0.1, 0.75), (0.5, 0.75), (0.8, 1.2)] {
            let s = NonUniformScheme::two_bit(w);
            let p = s.collision_probability(rho);
            let want = p_w2(rho, w);
            assert!((p - want).abs() < 1e-7, "P at rho={rho}: {p} vs {want}");
            let v = s.variance_factor(rho);
            let want_v = v_w2(rho, w);
            assert!(
                ((v - want_v) / want_v).abs() < 1e-3,
                "V at rho={rho}: {v} vs {want_v}"
            );
        }
    }

    #[test]
    fn encode_regions_and_cardinality() {
        let s = NonUniformScheme::new(vec![0.5, 1.5]);
        assert_eq!(s.cardinality(), 6);
        assert_eq!(s.bits_per_code(), 3);
        assert_eq!(s.encode_one(-2.0), 0);
        assert_eq!(s.encode_one(-1.0), 1);
        assert_eq!(s.encode_one(-0.2), 2);
        assert_eq!(s.encode_one(0.2), 3);
        assert_eq!(s.encode_one(1.0), 4);
        assert_eq!(s.encode_one(2.0), 5);
    }

    #[test]
    fn collision_matches_monte_carlo() {
        use crate::data::pairs::bivariate_normal_batch;
        let s = NonUniformScheme::new(vec![0.4, 1.1]);
        let rho = 0.6;
        let (x, y) = bivariate_normal_batch(200_000, rho, 3);
        let cx = s.encode(&x);
        let cy = s.encode(&y);
        let rate = cx.iter().zip(&cy).filter(|(a, b)| a == b).count() as f64 / cx.len() as f64;
        let want = s.collision_probability(rho);
        assert!((rate - want).abs() < 5e-3, "{rate} vs {want}");
    }

    #[test]
    fn more_bits_never_hurt_at_optimum() {
        // Optimized 3-boundary (3-bit) variance ≤ optimized 1-boundary
        // (2-bit) variance: extra regions are free to collapse.
        for &rho in &[0.3, 0.7, 0.9] {
            let (_, v2) = NonUniformScheme::optimize_for(1, rho);
            let (_, v3) = NonUniformScheme::optimize_for(3, rho);
            assert!(
                v3 <= v2 * 1.02,
                "rho={rho}: 3-bit {v3} worse than 2-bit {v2}"
            );
        }
    }

    #[test]
    fn optimized_two_bit_matches_fig8() {
        // optimize_for(1, ρ) must agree with the Figure-8 grid search.
        use crate::theory::{optimum_w, SchemeKind};
        // ρ = 0.9: the 2-bit optimum is interior (Figure 8 right shows
        // w* ≈ 0.6-0.9 at high ρ); at mid ρ the curve is flat in w and
        // only V is comparable.
        let rho = 0.9;
        let (s, v) = NonUniformScheme::optimize_for(1, rho);
        let grid = optimum_w(SchemeKind::TwoBit, rho);
        assert!(
            (v - grid.v).abs() / grid.v < 0.02,
            "V: {v} vs grid {}",
            grid.v
        );
        assert!(
            (s.boundaries()[0] - grid.w).abs() < 0.2,
            "w: {} vs grid {}",
            s.boundaries()[0],
            grid.w
        );
        // Mid-ρ: V must still agree even though w* is non-identifiable.
        let (_, v5) = NonUniformScheme::optimize_for(1, 0.5);
        let g5 = optimum_w(SchemeKind::TwoBit, 0.5);
        assert!((v5 - g5.v).abs() / g5.v < 0.02, "V@0.5: {v5} vs {}", g5.v);
    }

    #[test]
    fn bit_budget_table_shape() {
        // At high ρ the hierarchy should be: more (well-spent) bits ⇒
        // smaller variance; the uniform scheme with optimal small w is
        // the many-bit frontier.
        let rows = bit_budget_table(0.9);
        assert_eq!(rows.len(), 4);
        let v1 = rows[0].2;
        let v2 = rows[1].2;
        let v3 = rows[2].2;
        assert!(v2 < v1, "2-bit {v2} should beat 1-bit {v1}");
        assert!(v3 <= v2 * 1.02, "3-bit {v3} vs 2-bit {v2}");
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive_boundary() {
        NonUniformScheme::new(vec![0.0, 1.0]);
    }
}
