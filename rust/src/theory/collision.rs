//! Collision probabilities for the four coding schemes.
//!
//! Notation follows the paper: `ρ ∈ [0, 1)` is the inner-product
//! similarity of unit-norm `u, v`; `d = ||u−v||² = 2(1−ρ)`; `w > 0` is
//! the quantization bin width.

use crate::mathx::{adaptive_simpson, phi_cdf, phi_pdf, SQRT_2PI};

/// Integration cutoff: `1 − Φ(9) ≈ 1.1e-19`, far below our tolerances.
const TAIL: f64 = 9.0;
/// Quadrature tolerance for the bin integrals.
const QTOL: f64 = 1e-12;

/// `Q_{s,t}(ρ) = Pr(x ∈ [s,t], y ∈ [s,t])` for standard bivariate normal
/// with correlation ρ — Lemma 1, Eq. (8).
pub fn q_interval(s: f64, t: f64, rho: f64) -> f64 {
    debug_assert!(t >= s);
    if rho >= 1.0 - 1e-13 {
        return phi_cdf(t) - phi_cdf(s);
    }
    let sigma = (1.0 - rho * rho).sqrt();
    let lo = s.max(-TAIL);
    let hi = t.min(TAIL);
    if hi <= lo {
        return 0.0;
    }
    adaptive_simpson(
        |z| {
            phi_pdf(z)
                * (phi_cdf((t - rho * z) / sigma) - phi_cdf((s - rho * z) / sigma))
        },
        lo,
        hi,
        QTOL,
        40,
    )
}

/// `∂Q_{s,t}/∂ρ` — Lemma 1, Eq. (9). Always ≥ 0 (monotonicity).
pub fn dq_interval_drho(s: f64, t: f64, rho: f64) -> f64 {
    let rho = rho.min(1.0 - 1e-12);
    let one_m_r2 = 1.0 - rho * rho;
    let a = (-t * t / (1.0 + rho)).exp();
    let b = (-s * s / (1.0 + rho)).exp();
    let c = (-(t * t + s * s - 2.0 * s * t * rho) / (2.0 * one_m_r2)).exp();
    (a + b - 2.0 * c) / (2.0 * std::f64::consts::PI * one_m_r2.sqrt())
}

/// `P_w(ρ)` — collision probability of uniform quantization `h_w`
/// (Theorem 1, Eq. 10): `2 Σ_{i≥0} Q_{iw,(i+1)w}(ρ)`.
///
/// The series is truncated once the bin leaves `[−TAIL, TAIL]`.
pub fn p_w(rho: f64, w: f64) -> f64 {
    assert!(w > 0.0, "p_w: w must be positive");
    assert!((0.0..=1.0).contains(&rho), "p_w: rho in [0,1]");
    if rho >= 1.0 - 1e-13 {
        return 1.0;
    }
    let imax = (TAIL / w).ceil() as usize;
    let mut acc = 0.0;
    for i in 0..=imax {
        let s = i as f64 * w;
        let t = (i as f64 + 1.0) * w;
        acc += q_interval(s, t, rho);
        if s > TAIL {
            break;
        }
    }
    (2.0 * acc).min(1.0)
}

/// `P_{w,q}(ρ)` — collision probability of the window-and-offset scheme
/// `h_{w,q}` of Datar et al., closed form (Eq. 7):
///
/// ```text
/// P_{w,q} = 2Φ(t) − 1 − 2/(√(2π) t) + (2/t) φ(t),   t = w/√d,  d = 2(1−ρ)
/// ```
pub fn p_wq(rho: f64, w: f64) -> f64 {
    assert!(w > 0.0, "p_wq: w must be positive");
    assert!((0.0..=1.0).contains(&rho), "p_wq: rho in [0,1]");
    let d = 2.0 * (1.0 - rho);
    if d <= 0.0 {
        return 1.0;
    }
    let t = w / d.sqrt();
    (2.0 * phi_cdf(t) - 1.0 - 2.0 / (SQRT_2PI * t) + 2.0 / t * phi_pdf(t)).clamp(0.0, 1.0)
}

/// `P_{w,2}(ρ)` — collision probability of the 2-bit non-uniform scheme
/// `h_{w,2}` (Theorem 4, Eq. 17):
///
/// ```text
/// P_{w,2} = 1 − acos(ρ)/π − 4 ∫_0^w φ(z) Φ((−w + ρz)/√(1−ρ²)) dz
/// ```
pub fn p_w2(rho: f64, w: f64) -> f64 {
    assert!(w >= 0.0, "p_w2: w must be non-negative");
    assert!((0.0..=1.0).contains(&rho), "p_w2: rho in [0,1]");
    if rho >= 1.0 - 1e-13 {
        return 1.0;
    }
    let base = 1.0 - rho.acos() / std::f64::consts::PI;
    if w == 0.0 {
        return base;
    }
    let sigma = (1.0 - rho * rho).sqrt();
    let hi = w.min(TAIL);
    let integral = adaptive_simpson(
        |z| phi_pdf(z) * phi_cdf((-w + rho * z) / sigma),
        0.0,
        hi,
        QTOL,
        40,
    );
    (base - 4.0 * integral).clamp(0.0, 1.0)
}

/// `P_1(ρ) = 1 − acos(ρ)/π` — the 1-bit (sign) collision probability
/// (Eq. 19; Goemans–Williamson).
pub fn p_1(rho: f64) -> f64 {
    assert!((-1.0..=1.0).contains(&rho));
    1.0 - rho.acos() / std::f64::consts::PI
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    #[test]
    fn p_w_rho0_closed_form() {
        // Theorem 1, Eq. (11): P_w|ρ=0 = 2 Σ (Φ((i+1)w) − Φ(iw))².
        for &w in &[0.25, 0.5, 1.0, 2.0, 4.0] {
            let want: f64 = 2.0
                * (0..200)
                    .map(|i| {
                        let a = phi_cdf((i + 1) as f64 * w) - phi_cdf(i as f64 * w);
                        a * a
                    })
                    .sum::<f64>();
            let got = p_w(0.0, w);
            assert!((got - want).abs() < 1e-9, "w={w}: {got} vs {want}");
        }
    }

    #[test]
    fn p_w_limits() {
        // As w→∞, h_w degenerates to sign coding ⇒ P_w → P_1.
        for &rho in &[0.0, 0.3, 0.7, 0.9] {
            let got = p_w(rho, 50.0);
            assert!((got - p_1(rho)).abs() < 1e-9, "rho={rho}");
        }
        // ρ = 1 ⇒ always collide.
        assert_eq!(p_w(1.0, 1.0), 1.0);
        // w → 0 ⇒ collisions vanish (for ρ < 1).
        assert!(p_w(0.5, 1e-3) < 2e-3);
    }

    #[test]
    fn p_w_monotone_in_rho() {
        for &w in &[0.5, 1.0, 3.0] {
            let mut prev = -1.0;
            for i in 0..=20 {
                let rho = i as f64 / 20.0 * 0.99;
                let p = p_w(rho, w);
                assert!(p >= prev - 1e-12, "w={w} rho={rho}");
                prev = p;
            }
        }
    }

    #[test]
    fn p_w_rho0_limit_half() {
        // Figure 1: at ρ=0, P_w approaches 1/2 quickly as w grows.
        assert!((p_w(0.0, 6.0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn p_wq_matches_integral_form() {
        // Eq. (6): P_{w,q} = ∫_0^w (2/√d) φ(t/√d)(1 − t/w) dt.
        for &(rho, w) in &[(0.0, 0.5), (0.25, 1.0), (0.5, 2.0), (0.9, 4.0)] {
            let d: f64 = 2.0 * (1.0 - rho);
            let sd = d.sqrt();
            let want = adaptive_simpson(
                |t| 2.0 / sd * phi_pdf(t / sd) * (1.0 - t / w),
                0.0,
                w,
                1e-12,
                40,
            );
            let got = p_wq(rho, w);
            assert!((got - want).abs() < 1e-9, "rho={rho} w={w}: {got} vs {want}");
        }
    }

    #[test]
    fn p_wq_to_one_as_w_grows() {
        // The paper's critique: even at ρ=0 the offset scheme collides
        // with probability → 1 for large w.
        assert!(p_wq(0.0, 50.0) > 0.97);
        assert!(p_wq(0.0, 500.0) > 0.997);
    }

    #[test]
    fn p_w2_limits_are_one_bit() {
        // Theorem 4 remark: w=0 and w=∞ both reduce to the sign scheme.
        for &rho in &[0.0, 0.4, 0.8, 0.95] {
            assert!((p_w2(rho, 0.0) - p_1(rho)).abs() < 1e-12);
            assert!((p_w2(rho, 30.0) - p_1(rho)).abs() < 1e-9, "rho={rho}");
        }
    }

    #[test]
    fn p_w2_equals_quadrant_sum() {
        // Cross-check against bivariate rectangle probabilities:
        // P_{w,2} = Σ over the 4 regions of Pr(both in region).
        use crate::mathx::normal::bvn_rect;
        use std::f64::{INFINITY, NEG_INFINITY};
        for &(rho, w) in &[(0.0, 0.75), (0.5, 0.75), (0.8, 1.5), (0.3, 0.25)] {
            let regions = [
                (NEG_INFINITY, -w),
                (-w, 0.0),
                (0.0, w),
                (w, INFINITY),
            ];
            let want: f64 = regions
                .iter()
                .map(|&(a, b)| bvn_rect(a, b, a, b, rho))
                .sum();
            let got = p_w2(rho, w);
            assert!(
                (got - want).abs() < 1e-8,
                "rho={rho} w={w}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn p_1_reference() {
        assert!((p_1(0.0) - 0.5).abs() < 1e-15);
        assert!((p_1(1.0) - 1.0).abs() < 1e-15);
        assert!((p_1(0.5) - (1.0 - (0.5f64).acos() / PI)).abs() < 1e-15);
    }

    #[test]
    fn monotonicity_all_schemes() {
        for scheme in crate::theory::SchemeKind::ALL {
            let mut prev = -1.0;
            for i in 0..=30 {
                let rho = i as f64 / 30.0 * 0.995;
                let p = scheme.collision_probability(rho, 0.75);
                assert!(
                    p >= prev - 1e-10,
                    "{:?} not monotone at rho={rho}",
                    scheme
                );
                prev = p;
            }
        }
    }

    #[test]
    fn dq_nonnegative() {
        for &(s, t) in &[(0.0, 0.5), (0.5, 1.0), (2.0, 3.0)] {
            for i in 0..10 {
                let rho = i as f64 / 10.0;
                assert!(dq_interval_drho(s, t, rho) >= -1e-15);
            }
        }
    }

    #[test]
    fn dq_matches_numeric_derivative() {
        for &(s, t, rho) in &[(0.0, 1.0, 0.3), (1.0, 2.0, 0.6), (0.5, 1.5, 0.1)] {
            let h = 1e-5;
            let num = (q_interval(s, t, rho + h) - q_interval(s, t, rho - h)) / (2.0 * h);
            let ana = dq_interval_drho(s, t, rho);
            assert!(
                (num - ana).abs() < 1e-6,
                "s={s} t={t} rho={rho}: {num} vs {ana}"
            );
        }
    }
}
