//! Monotone ρ ↔ collision-probability inversion.
//!
//! Section 3 of the paper: "Since there is a one-to-one mapping between
//! ρ and P_w, we can tabulate P_w for each ρ (for example, at a precision
//! of 10⁻³). From k independent projections, we can compute the empirical
//! P̂ and find the estimate from the tables." This module provides both
//! the tabulated fast path (used on the serving hot path) and an
//! on-demand bisection fallback (used for tests and one-off estimates).

use super::SchemeKind;
use crate::mathx::bisect;

/// Invert `P(ρ) = p_hat` for ρ by bisection over `[0, 1]`.
///
/// The empirical collision rate is clamped into the feasible range
/// `[P(0), P(1)]` first — with finite `k` the empirical rate can fall
/// outside it (e.g. `P̂ < P(0)` when ρ ≈ 0 and the sample is unlucky).
pub fn rho_from_p(scheme: SchemeKind, w: f64, p_hat: f64) -> f64 {
    let p_lo = scheme.collision_probability(0.0, w);
    let p_hi = scheme.collision_probability(1.0 - 1e-12, w);
    let p = p_hat.clamp(p_lo.min(p_hi), p_lo.max(p_hi));
    if (p - p_lo).abs() < 1e-14 {
        return 0.0;
    }
    if (p - p_hi).abs() < 1e-14 {
        return 1.0;
    }
    bisect(
        |rho| scheme.collision_probability(rho, w) - p,
        0.0,
        1.0 - 1e-12,
        1e-10,
    )
}

/// Precomputed inversion table: `P` sampled on a uniform ρ grid, inverted
/// by binary search + linear interpolation. This is the hot-path
/// estimator backend — one table per `(scheme, w)` pair, built once.
#[derive(Clone, Debug)]
pub struct InversionTable {
    pub scheme: SchemeKind,
    pub w: f64,
    rhos: Vec<f64>,
    ps: Vec<f64>,
}

impl InversionTable {
    /// Build with `n` grid points (the paper suggests 10⁻³ precision;
    /// `n = 2048` comfortably exceeds that).
    pub fn build(scheme: SchemeKind, w: f64, n: usize) -> Self {
        assert!(n >= 8);
        let rhos: Vec<f64> = (0..n)
            .map(|i| i as f64 / (n - 1) as f64 * (1.0 - 1e-9))
            .collect();
        let ps: Vec<f64> = rhos
            .iter()
            .map(|&r| scheme.collision_probability(r, w))
            .collect();
        // Collision probabilities are non-decreasing in ρ (Lemma 1); make
        // that exact under floating-point so binary search is safe.
        let mut ps = ps;
        for i in 1..ps.len() {
            if ps[i] < ps[i - 1] {
                ps[i] = ps[i - 1];
            }
        }
        InversionTable { scheme, w, rhos, ps }
    }

    /// Default table size used across the system.
    pub fn build_default(scheme: SchemeKind, w: f64) -> Self {
        Self::build(scheme, w, 2048)
    }

    /// ρ̂ from an empirical collision rate (clamped into range).
    pub fn rho(&self, p_hat: f64) -> f64 {
        let n = self.ps.len();
        let p = p_hat.clamp(self.ps[0], self.ps[n - 1]);
        // Binary search for the bracketing segment.
        let idx = self.ps.partition_point(|&q| q < p);
        if idx == 0 {
            return self.rhos[0];
        }
        if idx >= n {
            return self.rhos[n - 1];
        }
        let (p0, p1) = (self.ps[idx - 1], self.ps[idx]);
        let (r0, r1) = (self.rhos[idx - 1], self.rhos[idx]);
        if p1 <= p0 {
            return r0;
        }
        r0 + (p - p0) / (p1 - p0) * (r1 - r0)
    }

    /// Forward lookup `P(ρ)` by interpolation (for tests/metrics).
    pub fn p(&self, rho: f64) -> f64 {
        let n = self.rhos.len();
        let r = rho.clamp(0.0, self.rhos[n - 1]);
        let t = r / self.rhos[n - 1] * (n - 1) as f64;
        let i = (t.floor() as usize).min(n - 2);
        let frac = t - i as f64;
        self.ps[i] * (1.0 - frac) + self.ps[i + 1] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bisection_roundtrip_all_schemes() {
        for scheme in SchemeKind::ALL {
            for &rho in &[0.05, 0.3, 0.56, 0.8, 0.95] {
                let w = 0.75;
                let p = scheme.collision_probability(rho, w);
                let back = rho_from_p(scheme, w, p);
                assert!(
                    (back - rho).abs() < 1e-7,
                    "{scheme:?} rho={rho}: back={back}"
                );
            }
        }
    }

    #[test]
    fn table_roundtrip_accuracy() {
        for scheme in SchemeKind::ALL {
            let t = InversionTable::build(scheme, 1.0, 2048);
            for &rho in &[0.02, 0.2, 0.5, 0.77, 0.93] {
                let p = scheme.collision_probability(rho, 1.0);
                let back = t.rho(p);
                assert!(
                    (back - rho).abs() < 2e-3,
                    "{scheme:?} rho={rho}: table gives {back}"
                );
            }
        }
    }

    #[test]
    fn clamping_out_of_range() {
        let t = InversionTable::build_default(SchemeKind::OneBit, 0.0);
        assert!(t.rho(0.0) <= 1e-9); // below P(0)=0.5 clamps to ρ=0
        assert!((t.rho(1.0) - 1.0).abs() < 1e-6);
        assert_eq!(rho_from_p(SchemeKind::OneBit, 0.0, 0.1), 0.0);
        assert_eq!(rho_from_p(SchemeKind::OneBit, 0.0, 1.0), 1.0);
    }

    #[test]
    fn forward_lookup_matches_exact() {
        let t = InversionTable::build(SchemeKind::Uniform, 0.75, 2048);
        for &rho in &[0.1, 0.5, 0.9] {
            let exact = SchemeKind::Uniform.collision_probability(rho, 0.75);
            assert!((t.p(rho) - exact).abs() < 1e-4);
        }
    }

    #[test]
    fn table_monotone_nondecreasing() {
        let t = InversionTable::build(SchemeKind::TwoBit, 0.5, 512);
        for i in 1..t.ps.len() {
            assert!(t.ps[i] >= t.ps[i - 1]);
        }
    }
}
