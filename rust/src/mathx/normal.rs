//! Standard normal pdf `φ`, cdf `Φ`, inverse cdf `Φ⁻¹`, and the
//! bivariate-normal rectangle probability used throughout the paper's
//! collision-probability derivations (Lemma 1 and its generalization).

use super::erf::erfc;
use super::quad::adaptive_simpson;

/// `√(2π)`.
pub const SQRT_2PI: f64 = 2.5066282746310005024157652848110;
/// `φ(0) = 1/√(2π)`.
pub const PHI0: f64 = 0.3989422804014326779399460599344;

/// Standard normal density `φ(x)`.
#[inline]
pub fn phi_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / SQRT_2PI
}

/// Standard normal cdf `Φ(x) = ½ erfc(-x/√2)`, accurate in both tails.
#[inline]
pub fn phi_cdf(x: f64) -> f64 {
    0.5 * erfc(-x * std::f64::consts::FRAC_1_SQRT_2)
}

/// Upper tail `1 - Φ(x)` without cancellation.
#[inline]
pub fn phi_sf(x: f64) -> f64 {
    0.5 * erfc(x * std::f64::consts::FRAC_1_SQRT_2)
}

/// Inverse standard normal cdf by Newton iteration seeded with a
/// logit-style initial guess; converges to ~1e-14 in a handful of steps.
/// Not on any hot path (used for tables and tests).
pub fn inv_phi_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "inv_phi_cdf domain: 0 < p < 1, got {p}");
    // Initial guess: crude rational logit approximation.
    let mut x = {
        let t = (p.min(1.0 - p)).max(1e-300);
        let s = (-2.0 * t.ln()).sqrt();
        let g = s - (2.30753 + 0.27061 * s) / (1.0 + 0.99229 * s + 0.04481 * s * s);
        if p < 0.5 {
            -g
        } else {
            g
        }
    };
    for _ in 0..60 {
        let f = phi_cdf(x) - p;
        let d = phi_pdf(x);
        if d == 0.0 {
            break;
        }
        let step = f / d;
        x -= step;
        if step.abs() < 1e-15 * (1.0 + x.abs()) {
            break;
        }
    }
    x
}

/// `Pr(X ∈ [a,b], Y ∈ [c,d])` for `(X,Y)` standard bivariate normal with
/// correlation `ρ` — the rectangle probability behind Lemma 1:
///
/// ```text
/// ∫_a^b φ(z) [ Φ((d−ρz)/√(1−ρ²)) − Φ((c−ρz)/√(1−ρ²)) ] dz
/// ```
///
/// Intervals may be infinite (use `f64::INFINITY` / `NEG_INFINITY`). The
/// finite integration range is clipped to `[-TAIL, TAIL]` with
/// `TAIL = 9` (`1 − Φ(9) ≈ 1e-19`, negligible at our tolerances).
pub fn bvn_rect(a: f64, b: f64, c: f64, d: f64, rho: f64) -> f64 {
    assert!(b >= a && d >= c, "bvn_rect: empty rectangle");
    assert!((-1.0..=1.0).contains(&rho), "bvn_rect: |rho| <= 1");
    const TAIL: f64 = 9.0;
    if rho.abs() >= 1.0 - 1e-13 {
        // Degenerate: Y = ±X exactly.
        let (lo, hi) = if rho > 0.0 {
            (a.max(c), b.min(d))
        } else {
            (a.max(-d), b.min(-c))
        };
        if hi <= lo {
            return 0.0;
        }
        return phi_cdf(hi) - phi_cdf(lo);
    }
    let s = (1.0 - rho * rho).sqrt();
    let lo = a.max(-TAIL);
    let hi = b.min(TAIL);
    if hi <= lo {
        return 0.0;
    }
    let f = |z: f64| {
        let upper = if d.is_infinite() {
            1.0
        } else {
            phi_cdf((d - rho * z) / s)
        };
        let lower = if c.is_infinite() {
            0.0
        } else {
            phi_cdf((c - rho * z) / s)
        };
        phi_pdf(z) * (upper - lower)
    };
    adaptive_simpson(f, lo, hi, 1e-12, 40)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::{INFINITY, NEG_INFINITY};

    #[test]
    fn cdf_reference_values() {
        assert!((phi_cdf(0.0) - 0.5).abs() < 1e-15);
        // Φ(1) = 0.841344746068542948585232545632 (mpmath)
        assert!((phi_cdf(1.0) - 0.841344746068542948585232545632).abs() < 1e-14);
        // Φ(-2) = 0.0227501319481792072002826011927
        assert!((phi_cdf(-2.0) - 0.0227501319481792072002826011927).abs() < 1e-14);
        // paper: 1 - Φ(3) ≈ 1.35e-3 (paper rounds to 10^-3)
        assert!((phi_sf(3.0) - 1.349898031630094526651814767e-3).abs() < 1e-15);
        // paper: 1 - Φ(6) = 9.9e-10
        let t = phi_sf(6.0);
        assert!((t / 9.865876450376946e-10 - 1.0).abs() < 1e-9, "{t}");
    }

    #[test]
    fn inverse_roundtrip() {
        for &p in &[1e-10, 1e-4, 0.01, 0.3, 0.5, 0.7, 0.99, 1.0 - 1e-6] {
            let x = inv_phi_cdf(p);
            assert!(
                (phi_cdf(x) - p).abs() < 1e-12 * (1.0 + 1.0 / p.min(1.0 - p)),
                "roundtrip at p={p}: x={x}"
            );
        }
    }

    #[test]
    fn bvn_rect_independent_factorizes() {
        // ρ = 0 ⇒ P = (Φ(b)-Φ(a)) (Φ(d)-Φ(c)).
        let got = bvn_rect(-0.5, 1.0, 0.2, 2.0, 0.0);
        let want = (phi_cdf(1.0) - phi_cdf(-0.5)) * (phi_cdf(2.0) - phi_cdf(0.2));
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    #[test]
    fn bvn_rect_quadrant_sheppard() {
        // Sheppard: Pr(X>0, Y>0) = 1/4 + asin(ρ)/(2π).
        for &rho in &[0.0, 0.3, 0.7, 0.95] {
            let got = bvn_rect(0.0, INFINITY, 0.0, INFINITY, rho);
            let want = 0.25 + rho.asin() / (2.0 * std::f64::consts::PI);
            assert!((got - want).abs() < 1e-9, "rho={rho}: {got} vs {want}");
        }
    }

    #[test]
    fn bvn_rect_full_plane_is_one() {
        for &rho in &[0.0, 0.5, 0.9] {
            let got = bvn_rect(NEG_INFINITY, INFINITY, NEG_INFINITY, INFINITY, rho);
            assert!((got - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn bvn_rect_degenerate_rho_one() {
        let got = bvn_rect(0.0, 1.0, 0.5, 2.0, 1.0);
        let want = phi_cdf(1.0) - phi_cdf(0.5);
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn bvn_rect_symmetry_in_coords() {
        let p1 = bvn_rect(-0.3, 0.9, 0.1, 1.4, 0.6);
        let p2 = bvn_rect(0.1, 1.4, -0.3, 0.9, 0.6);
        assert!((p1 - p2).abs() < 1e-10);
    }
}
