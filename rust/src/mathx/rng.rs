//! Reproducible random number generation.
//!
//! The projection matrix `R` (Eq. 1 of the paper, `r_ij ~ N(0,1)` i.i.d.)
//! must be *identical* across the pure-Rust path, the PJRT-artifact path,
//! and test oracles, and must be generatable chunk-by-chunk (the engine
//! streams D-tiles of `R` without materializing the whole matrix). We use
//! SplitMix64 for seeding, PCG64 (XSL-RR 128/64) as the base generator,
//! and a Box–Muller polar transform for normals.

/// SplitMix64 — used to expand a single `u64` seed into independent
/// stream seeds (Vigna's standard recommendation).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG64 (XSL-RR 128/64): 128-bit LCG state, 64-bit output.
/// Supports independent streams via the odd increment.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    /// Seed with `(seed, stream)`; distinct streams are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ 0xA02BDBF7BB3C0A7);
        let s_lo = sm.next_u64();
        let s_hi = sm.next_u64();
        let mut sm2 = SplitMix64::new(stream.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x5851F42D4C957F2D);
        let i_lo = sm2.next_u64();
        let i_hi = sm2.next_u64();
        let mut g = Pcg64 {
            state: 0,
            inc: (((i_hi as u128) << 64 | i_lo as u128) << 1) | 1,
        };
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g.state = g.state.wrapping_add((s_hi as u128) << 64 | s_lo as u128);
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1)` (never exactly 0 — safe for `ln`).
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 0.5) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift (unbiased
    /// enough for our workloads; exact rejection for small `n`).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // widening multiply rejection
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64;
            }
            // low slice: reject the biased region
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }
}

/// Standard-normal sampler (Marsaglia polar method) over a [`Pcg64`].
#[derive(Clone, Debug)]
pub struct NormalSampler {
    rng: Pcg64,
    cached: Option<f64>,
}

impl NormalSampler {
    pub fn new(seed: u64, stream: u64) -> Self {
        NormalSampler {
            rng: Pcg64::new(seed, stream),
            cached: None,
        }
    }

    /// One `N(0,1)` draw.
    #[inline]
    pub fn next(&mut self) -> f64 {
        if let Some(v) = self.cached.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.rng.next_f64() - 1.0;
            let v = 2.0 * self.rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.cached = Some(v * m);
                return u * m;
            }
        }
    }

    /// Fill `out` with i.i.d. `N(0,1)` as f32 (the artifact dtype).
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for o in out.iter_mut() {
            *o = self.next() as f32;
        }
    }

    /// Access the underlying uniform generator (e.g. for the `h_{w,q}`
    /// offsets `q_j ~ U(0, w)`).
    pub fn rng_mut(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_deterministic_and_stream_independent() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        let mut c = Pcg64::new(42, 1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut g = Pcg64::new(1, 7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut g = Pcg64::new(3, 3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = g.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut s = NormalSampler::new(9, 0);
        let n = 200_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = s.next();
            m1 += x;
            m2 += x * x;
            m4 += x * x * x * x;
        }
        let nf = n as f64;
        assert!((m1 / nf).abs() < 0.01, "mean {}", m1 / nf);
        assert!((m2 / nf - 1.0).abs() < 0.02, "var {}", m2 / nf);
        assert!((m4 / nf - 3.0).abs() < 0.15, "kurt {}", m4 / nf);
    }

    #[test]
    fn normal_cdf_agreement() {
        // Empirical CDF at a few points vs Φ — a crude K-S style check.
        let mut s = NormalSampler::new(123, 5);
        let n = 100_000;
        let draws: Vec<f64> = (0..n).map(|_| s.next()).collect();
        for &t in &[-2.0, -1.0, 0.0, 0.5, 1.5] {
            let emp = draws.iter().filter(|&&x| x <= t).count() as f64 / n as f64;
            let want = crate::mathx::phi_cdf(t);
            assert!((emp - want).abs() < 0.01, "t={t}: {emp} vs {want}");
        }
    }
}
