//! Root finding for the monotone ρ ↔ collision-probability inversions.
//! All collision probabilities in the paper are strictly increasing in ρ
//! (Lemma 1), so bracketing methods are exact-fit here.

/// Bisection on `[a, b]`; requires `f(a)` and `f(b)` to straddle zero.
/// Returns the midpoint after the bracket shrinks below `tol`.
pub fn bisect<F: Fn(f64) -> f64>(f: F, mut a: f64, mut b: f64, tol: f64) -> f64 {
    let mut fa = f(a);
    let fb = f(b);
    assert!(
        fa * fb <= 0.0,
        "bisect: no sign change on [{a}, {b}] (f(a)={fa}, f(b)={fb})"
    );
    if fa == 0.0 {
        return a;
    }
    if fb == 0.0 {
        return b;
    }
    for _ in 0..200 {
        let m = 0.5 * (a + b);
        let fm = f(m);
        if fm == 0.0 || (b - a) < tol {
            return m;
        }
        if fa * fm < 0.0 {
            b = m;
        } else {
            a = m;
            fa = fm;
        }
    }
    0.5 * (a + b)
}

/// Newton iteration with a bisection safety net: falls back to bisection
/// whenever the Newton step leaves the bracket or the derivative is tiny.
/// `fdf` returns `(f(x), f'(x))`.
pub fn newton_bisect_fallback<F: Fn(f64) -> (f64, f64)>(
    fdf: F,
    mut a: f64,
    mut b: f64,
    x0: f64,
    tol: f64,
) -> f64 {
    let mut x = x0.clamp(a, b);
    for _ in 0..100 {
        let (fx, dfx) = fdf(x);
        if fx == 0.0 {
            return x;
        }
        // Maintain the bracket.
        let (fa, _) = fdf(a);
        if fa * fx < 0.0 {
            b = x;
        } else {
            a = x;
        }
        let newton_ok = dfx.abs() > 1e-300;
        let xn = if newton_ok { x - fx / dfx } else { f64::NAN };
        let next = if newton_ok && xn > a && xn < b {
            xn
        } else {
            0.5 * (a + b)
        };
        if (next - x).abs() < tol * (1.0 + x.abs()) {
            return next;
        }
        x = next;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bisect_sqrt2() {
        let r = bisect(|x| x * x - 2.0, 0.0, 2.0, 1e-13);
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn bisect_endpoint_root() {
        assert_eq!(bisect(|x| x, 0.0, 1.0, 1e-12), 0.0);
    }

    #[test]
    fn newton_converges_fast() {
        let r = newton_bisect_fallback(|x| (x * x - 2.0, 2.0 * x), 0.0, 2.0, 1.0, 1e-14);
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn newton_falls_back_on_flat_derivative() {
        // f(x) = x³ has f'(0) = 0; start right at the flat point.
        let r = newton_bisect_fallback(|x| (x * x * x, 3.0 * x * x), -1.0, 2.0, 0.0, 1e-12);
        assert!(r.abs() < 1e-6, "{r}");
    }

    #[test]
    #[should_panic(expected = "no sign change")]
    fn bisect_rejects_bad_bracket() {
        bisect(|x| x * x + 1.0, -1.0, 1.0, 1e-12);
    }
}
