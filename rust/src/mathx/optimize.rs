//! 1-D minimization. The paper repeatedly needs `argmin_w V(w; ρ)` over a
//! half-line (Figures 5, 8, 9). The variance curves are smooth but can be
//! extremely flat (the paper notes `V_w` is insensitive to `w` once
//! `w > 1∼2`, with the ρ=0 optimum at `w → ∞`), so we bracket on a coarse
//! grid first and then polish with golden-section.

/// Golden-section minimization of `f` on `[a, b]`.
/// Returns `(x_min, f(x_min))`.
pub fn golden_section_min<F: Fn(f64) -> f64>(f: F, mut a: f64, mut b: f64, tol: f64) -> (f64, f64) {
    assert!(b > a);
    const INVPHI: f64 = 0.6180339887498949; // 1/φ
    const INVPHI2: f64 = 0.3819660112501051; // 1/φ²
    let mut c = a + INVPHI2 * (b - a);
    let mut d = a + INVPHI * (b - a);
    let mut fc = f(c);
    let mut fd = f(d);
    while (b - a) > tol * (1.0 + a.abs() + b.abs()) {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = a + INVPHI2 * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + INVPHI * (b - a);
            fd = f(d);
        }
    }
    let x = 0.5 * (a + b);
    (x, f(x))
}

/// Coarse grid scan over `[lo, hi]` (`n` points, geometric if `log_grid`)
/// followed by golden-section polish around the best grid cell.
///
/// Robust to flat/multimodal curves as long as the grid resolves the
/// basins; the paper's variance curves are unimodal-or-flat in `w`.
pub fn grid_then_golden_min<F: Fn(f64) -> f64>(
    f: F,
    lo: f64,
    hi: f64,
    n: usize,
    log_grid: bool,
    tol: f64,
) -> (f64, f64) {
    assert!(hi > lo && n >= 3);
    let xs: Vec<f64> = (0..n)
        .map(|i| {
            let t = i as f64 / (n - 1) as f64;
            if log_grid {
                lo * (hi / lo).powf(t)
            } else {
                lo + t * (hi - lo)
            }
        })
        .collect();
    let mut best = 0usize;
    let mut best_f = f64::INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        let v = f(x);
        if v < best_f {
            best_f = v;
            best = i;
        }
    }
    let a = xs[best.saturating_sub(1)];
    let b = xs[(best + 1).min(n - 1)];
    if b > a {
        let (x, v) = golden_section_min(&f, a, b, tol);
        if v <= best_f {
            return (x, v);
        }
    }
    (xs[best], best_f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_quadratic() {
        let (x, v) = golden_section_min(|x| (x - 1.3).powi(2) + 0.5, -4.0, 6.0, 1e-10);
        assert!((x - 1.3).abs() < 1e-7, "{x}");
        assert!((v - 0.5).abs() < 1e-12);
    }

    #[test]
    fn golden_asymmetric() {
        let (x, _) = golden_section_min(|x| x.exp() - 2.0 * x, 0.0, 3.0, 1e-10);
        assert!((x - (2.0f64).ln()).abs() < 1e-7, "{x}");
    }

    #[test]
    fn grid_finds_global_among_bumps() {
        // Two minima; global at x ≈ 4.0.
        let f = |x: f64| ((x - 1.0).powi(2)).min((x - 4.0).powi(2) - 0.5);
        let (x, _) = grid_then_golden_min(f, 0.0, 6.0, 61, false, 1e-9);
        assert!((x - 4.0).abs() < 1e-5, "{x}");
    }

    #[test]
    fn grid_log_scale() {
        let f = |x: f64| (x.ln() - 2.0).powi(2);
        let (x, _) = grid_then_golden_min(f, 1e-2, 1e3, 101, true, 1e-10);
        assert!((x - (2.0f64).exp()).abs() < 1e-4, "{x}");
    }

    #[test]
    fn grid_flat_tail_returns_finite() {
        // Monotone decreasing to an asymptote — the V_w|ρ=0 situation.
        let f = |x: f64| 1.0 + (-x).exp();
        let (x, v) = grid_then_golden_min(f, 0.1, 50.0, 100, false, 1e-9);
        // f is numerically exactly 1.0 for x ≳ 37 (exp(-x) < f64 eps), so
        // the argmin is the first grid point in the flat region.
        assert!(x > 30.0, "optimum should push into the flat tail, got {x}");
        assert!(v <= 1.0 + 1e-12);
    }
}
