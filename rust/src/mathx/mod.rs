//! Self-contained numerical substrate.
//!
//! The paper's analysis (Theorems 1–4) needs the standard normal pdf/cdf,
//! bivariate-normal rectangle probabilities, numerical quadrature, 1-D
//! minimization and root finding, and a reproducible Gaussian sampler for
//! the projection matrices. Nothing here depends on external math crates —
//! every routine is implemented and unit-tested in this module tree.

pub mod erf;
pub mod normal;
pub mod quad;
pub mod optimize;
pub mod roots;
pub mod rng;

pub use erf::{erf, erfc};
pub use normal::{inv_phi_cdf, phi_cdf, phi_pdf, PHI0, SQRT_2PI};
pub use optimize::{golden_section_min, grid_then_golden_min};
pub use quad::{adaptive_simpson, gauss_legendre, GaussLegendre};
pub use roots::{bisect, newton_bisect_fallback};
pub use rng::{NormalSampler, Pcg64, SplitMix64};
