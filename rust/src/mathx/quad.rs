//! Numerical quadrature: adaptive Simpson (general-purpose, used for the
//! collision-probability integrals) and Gauss–Legendre with runtime node
//! computation (used where fixed-order speed matters, e.g. tabulating
//! ρ ↔ P inversion grids).

/// Adaptive Simpson's rule with Richardson error control.
///
/// `tol` is an absolute tolerance for the whole interval; `max_depth`
/// bounds recursion (40 is effectively "until machine precision").
pub fn adaptive_simpson<F: Fn(f64) -> f64>(f: F, a: f64, b: f64, tol: f64, max_depth: u32) -> f64 {
    if a == b {
        return 0.0;
    }
    let fa = f(a);
    let fb = f(b);
    let m = 0.5 * (a + b);
    let fm = f(m);
    let whole = (b - a) / 6.0 * (fa + 4.0 * fm + fb);
    simpson_rec(&f, a, b, fa, fb, fm, whole, tol, max_depth)
}

#[allow(clippy::too_many_arguments)]
fn simpson_rec<F: Fn(f64) -> f64>(
    f: &F,
    a: f64,
    b: f64,
    fa: f64,
    fb: f64,
    fm: f64,
    whole: f64,
    tol: f64,
    depth: u32,
) -> f64 {
    let m = 0.5 * (a + b);
    let lm = 0.5 * (a + m);
    let rm = 0.5 * (m + b);
    let flm = f(lm);
    let frm = f(rm);
    let left = (m - a) / 6.0 * (fa + 4.0 * flm + fm);
    let right = (b - m) / 6.0 * (fm + 4.0 * frm + fb);
    let delta = left + right - whole;
    if depth == 0 || delta.abs() <= 15.0 * tol {
        left + right + delta / 15.0
    } else {
        simpson_rec(f, a, m, fa, fm, flm, left, 0.5 * tol, depth - 1)
            + simpson_rec(f, m, b, fm, fb, frm, right, 0.5 * tol, depth - 1)
    }
}

/// Precomputed Gauss–Legendre rule of order `n` on `[-1, 1]`.
///
/// Nodes are the roots of the Legendre polynomial `P_n`, found by Newton
/// iteration from the Chebyshev-like initial guess
/// `cos(π (i − 1/4)/(n + 1/2))`; weights are `2 / ((1−x²) P_n'(x)²)`.
pub struct GaussLegendre {
    nodes: Vec<f64>,
    weights: Vec<f64>,
}

impl GaussLegendre {
    /// Build an `n`-point rule. `n ≥ 1`.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let mut nodes = vec![0.0; n];
        let mut weights = vec![0.0; n];
        let m = (n + 1) / 2;
        for i in 0..m {
            // Initial guess for the i-th root (descending order).
            let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
            let mut pp = 0.0;
            for _ in 0..100 {
                // Evaluate P_n(x) and P_n'(x) by the three-term recurrence.
                let mut p0 = 1.0;
                let mut p1 = 0.0;
                for j in 0..n {
                    let p2 = p1;
                    p1 = p0;
                    p0 = ((2.0 * j as f64 + 1.0) * x * p1 - j as f64 * p2) / (j as f64 + 1.0);
                }
                pp = n as f64 * (x * p0 - p1) / (x * x - 1.0);
                let dx = p0 / pp;
                x -= dx;
                if dx.abs() < 1e-15 {
                    break;
                }
            }
            nodes[i] = -x;
            nodes[n - 1 - i] = x;
            let w = 2.0 / ((1.0 - x * x) * pp * pp);
            weights[i] = w;
            weights[n - 1 - i] = w;
        }
        GaussLegendre { nodes, weights }
    }

    /// Integrate `f` over `[a, b]` with this rule.
    pub fn integrate<F: Fn(f64) -> f64>(&self, f: F, a: f64, b: f64) -> f64 {
        let half = 0.5 * (b - a);
        let mid = 0.5 * (a + b);
        let mut acc = 0.0;
        for (&x, &w) in self.nodes.iter().zip(&self.weights) {
            acc += w * f(mid + half * x);
        }
        acc * half
    }
}

/// One-shot Gauss–Legendre integration (builds the rule each call; prefer
/// caching a [`GaussLegendre`] when integrating many times).
pub fn gauss_legendre<F: Fn(f64) -> f64>(f: F, a: f64, b: f64, n: usize) -> f64 {
    GaussLegendre::new(n).integrate(f, a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simpson_polynomial_exact() {
        // Simpson is exact for cubics.
        let got = adaptive_simpson(|x| x * x * x - 2.0 * x + 1.0, -1.0, 3.0, 1e-12, 10);
        // ∫ = x⁴/4 − x² + x | = (81/4 − 9 + 3) − (1/4 − 1 − 1) = 20.25 − 6 + 1.75 = 16
        assert!((got - 16.0).abs() < 1e-10, "{got}");
    }

    #[test]
    fn simpson_oscillatory() {
        let got = adaptive_simpson(|x| (10.0 * x).sin(), 0.0, std::f64::consts::PI, 1e-12, 40);
        let want = (1.0 - (10.0 * std::f64::consts::PI).cos()) / 10.0;
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    #[test]
    fn simpson_gaussian_integral() {
        let got = adaptive_simpson(
            |x| (-0.5 * x * x).exp(),
            -9.0,
            9.0,
            1e-13,
            40,
        );
        assert!((got - crate::mathx::SQRT_2PI).abs() < 1e-10, "{got}");
    }

    #[test]
    fn gl_nodes_symmetric_weights_sum() {
        for &n in &[1usize, 2, 5, 16, 41, 64] {
            let gl = GaussLegendre::new(n);
            let sum: f64 = gl.weights.iter().sum();
            assert!((sum - 2.0).abs() < 1e-12, "n={n} weight sum {sum}");
            for i in 0..n {
                assert!(
                    (gl.nodes[i] + gl.nodes[n - 1 - i]).abs() < 1e-12,
                    "n={n} node symmetry"
                );
            }
        }
    }

    #[test]
    fn gl_exact_for_degree_2n_minus_1() {
        // 5-point GL integrates degree-9 polynomials exactly.
        let gl = GaussLegendre::new(5);
        let got = gl.integrate(|x| x.powi(9) + 3.0 * x.powi(8), -1.0, 1.0);
        let want = 2.0 * 3.0 / 9.0; // odd term vanishes; ∫x⁸ = 2/9
        assert!((got - want).abs() < 1e-13, "{got} vs {want}");
    }

    #[test]
    fn gl_matches_simpson_on_smooth() {
        let f = |x: f64| (x.sin() + 2.0).ln();
        let a = 0.3;
        let b = 2.7;
        let s = adaptive_simpson(f, a, b, 1e-13, 40);
        let g = gauss_legendre(f, a, b, 41);
        assert!((s - g).abs() < 1e-11, "{s} vs {g}");
    }
}
