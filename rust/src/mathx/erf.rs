//! Error function to near machine precision via the regularized
//! incomplete gamma function: `erf(x) = P(1/2, x²)` for `x ≥ 0`.
//!
//! We use the classic series / continued-fraction split (Numerical-Recipes
//! style `gser`/`gcf`): the power series converges quickly for `x² < 1.5`
//! and the Lentz continued fraction elsewhere. Both iterate to relative
//! tolerance `3e-16`, giving |erf| accurate to ~1 ulp over the whole range —
//! accurate enough that the paper's analytic constants (e.g. the
//! `V_{w,q}` minimum `7.6797` and `V_w|ρ=0 → π²/4`) reproduce to every
//! printed digit.

const EPS: f64 = 3.0e-16;
const ITMAX: usize = 400;
/// ln Γ(1/2) = ln √π.
const LN_GAMMA_HALF: f64 = 0.5723649429247000870717136756765293558;

/// Regularized lower incomplete gamma `P(a, x)` by power series.
/// Converges for `x < a + 1`.
fn gamma_p_series(a: f64, x: f64, ln_gamma_a: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..ITMAX {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * EPS {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma_a).exp()
}

/// Regularized upper incomplete gamma `Q(a, x)` by modified Lentz
/// continued fraction. Converges for `x ≥ a + 1`.
fn gamma_q_contfrac(a: f64, x: f64, ln_gamma_a: f64) -> f64 {
    const FPMIN: f64 = 1.0e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / FPMIN;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..=ITMAX {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = b + an / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma_a).exp() * h
}

/// Error function, `erf(x) = 2/√π ∫_0^x e^{-t²} dt`.
pub fn erf(x: f64) -> f64 {
    if x == 0.0 {
        return 0.0;
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x2 = x * x;
    let p = if x2 < 1.5 {
        gamma_p_series(0.5, x2, LN_GAMMA_HALF)
    } else {
        1.0 - gamma_q_contfrac(0.5, x2, LN_GAMMA_HALF)
    };
    sign * p
}

/// Complementary error function, `erfc(x) = 1 - erf(x)`, computed without
/// cancellation for large positive `x` (down to ~1e-300).
pub fn erfc(x: f64) -> f64 {
    if x == 0.0 {
        return 1.0;
    }
    let x2 = x * x;
    if x > 0.0 {
        if x2 < 1.5 {
            1.0 - gamma_p_series(0.5, x2, LN_GAMMA_HALF)
        } else {
            gamma_q_contfrac(0.5, x2, LN_GAMMA_HALF)
        }
    } else {
        2.0 - erfc(-x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values computed with mpmath at 30 digits.
    const CASES: &[(f64, f64)] = &[
        (0.1, 0.112462916018284892203275071744),
        (0.5, 0.520499877813046537682746653892),
        (1.0, 0.842700792949714869341220635083),
        (1.5, 0.966105146475310727066976261646),
        (2.0, 0.995322265018952734162069256367),
        (3.0, 0.999977909503001414558627223870),
        (4.0, 0.999999984582742099719981147840),
    ];

    #[test]
    fn erf_matches_reference() {
        for &(x, want) in CASES {
            let got = erf(x);
            assert!(
                (got - want).abs() < 1e-14,
                "erf({x}) = {got}, want {want}"
            );
            assert!((erf(-x) + want).abs() < 1e-14, "erf odd symmetry at {x}");
        }
    }

    #[test]
    fn erfc_complements_erf_midrange() {
        for &(x, want) in CASES {
            let got = erfc(x);
            assert!(
                (got - (1.0 - want)).abs() < 1e-14,
                "erfc({x}) = {got}"
            );
        }
    }

    #[test]
    fn erfc_large_tail_no_cancellation() {
        // erfc(6) = 2.1519736712498913116593350399e-17 (mpmath)
        let got = erfc(6.0);
        let want = 2.1519736712498913116593350399e-17;
        assert!(
            ((got - want) / want).abs() < 1e-12,
            "erfc(6) rel err too big: {got}"
        );
        // erfc(10) = 2.0884875837625447570007862949e-45
        let got = erfc(10.0);
        let want = 2.0884875837625447570007862949e-45;
        assert!(((got - want) / want).abs() < 1e-12, "erfc(10): {got}");
    }

    #[test]
    fn erfc_negative_arg() {
        assert!((erfc(-1.0) - (2.0 - erfc(1.0))).abs() < 1e-15);
        assert!((erfc(-6.0) - 2.0).abs() < 1e-15);
    }

    #[test]
    fn erf_limits() {
        assert_eq!(erf(0.0), 0.0);
        assert!((erf(30.0) - 1.0).abs() < 1e-15);
        assert!((erf(-30.0) + 1.0).abs() < 1e-15);
    }
}
