//! Recall / probe-cost evaluation of LSH with different coding schemes —
//! the near-neighbor comparison the paper motivates in Section 1.1.

use super::search::{LshIndex, LshParams};
use crate::mathx::NormalSampler;

/// One evaluation row: recall@n and candidate fraction for a scheme.
#[derive(Clone, Debug)]
pub struct LshEvalResult {
    pub scheme: String,
    pub w: f64,
    pub k_per_table: usize,
    pub n_tables: usize,
    pub recall_at_10: f64,
    /// Mean fraction of the corpus examined as candidates per query.
    pub candidate_frac: f64,
    pub n_queries: usize,
}

/// Build an index over a random corpus (with planted near-duplicate
/// pairs) and measure recall@10 against brute force plus candidate cost.
pub fn evaluate_lsh(
    params: LshParams,
    corpus_n: usize,
    dim: usize,
    n_queries: usize,
    seed: u64,
) -> LshEvalResult {
    evaluate_lsh_noise(params, corpus_n, dim, n_queries, seed, 0.05)
}

/// As [`evaluate_lsh`] with an explicit per-coordinate query noise σ.
/// The query-to-base cosine is `1/√(1 + dim·σ²)`; σ = 0.05 at dim = 64
/// gives ρ ≈ 0.93 — the high-similarity regime the paper targets.
pub fn evaluate_lsh_noise(
    params: LshParams,
    corpus_n: usize,
    dim: usize,
    n_queries: usize,
    seed: u64,
    noise: f64,
) -> LshEvalResult {
    let mut idx = LshIndex::new(params.clone());
    let mut ns = NormalSampler::new(seed, 0x15);
    let mut corpus: Vec<Vec<f32>> = Vec::with_capacity(corpus_n);
    for _ in 0..corpus_n {
        let mut v: Vec<f32> = (0..dim).map(|_| ns.next() as f32).collect();
        let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        for x in &mut v {
            *x /= n;
        }
        corpus.push(v);
    }
    // Plant near-duplicates: queries are noisy copies of corpus items.
    for v in &corpus {
        idx.insert(v);
    }
    // Recall of the planted near-duplicate: each query is a noisy copy
    // of corpus item q; success = that item appears in the LSH top-10.
    // (This is the duplicate-detection task the paper's high-similarity
    // regime targets; top-10 overlap against random non-neighbors would
    // measure noise, not the hash.)
    let mut recall_sum = 0.0;
    let mut cand_sum = 0.0;
    for q in 0..n_queries {
        let base_id = (q % corpus_n) as u32;
        let noisy: Vec<f32> = corpus[base_id as usize]
            .iter()
            .map(|&x| x + (noise * ns.next()) as f32)
            .collect();
        let got = idx.query(&noisy, 10);
        if got.iter().any(|&(id, _)| id == base_id) {
            recall_sum += 1.0;
        }
        let (cands, _) = idx.candidates(&noisy);
        cand_sum += cands.len() as f64 / corpus_n as f64;
    }
    LshEvalResult {
        scheme: params.coding.scheme.label().to_string(),
        w: params.coding.w,
        k_per_table: params.k_per_table,
        n_tables: params.n_tables,
        recall_at_10: recall_sum / n_queries as f64,
        candidate_frac: cand_sum / n_queries as f64,
        n_queries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodingParams, Scheme};

    #[test]
    fn reasonable_recall_with_enough_tables() {
        // σ = 0.05 at dim 48 ⇒ query-base ρ ≈ 0.95; P_{w,2}(0.95, 0.75)
        // ≈ 0.73 ⇒ per-table hit 0.73⁴ ≈ 0.29 ⇒ over 10 tables ≈ 0.97.
        let params = LshParams {
            coding: CodingParams::new(Scheme::TwoBit, 0.75),
            k_per_table: 4,
            n_tables: 10,
            seed: 9,
        };
        let r = evaluate_lsh_noise(params, 150, 48, 20, 3, 0.05);
        assert!(r.recall_at_10 > 0.6, "recall {}", r.recall_at_10);
        assert!(r.candidate_frac < 1.0);
    }

    #[test]
    fn more_tables_more_recall_more_cost() {
        let base = LshParams {
            coding: CodingParams::new(Scheme::OneBit, 0.0),
            k_per_table: 8,
            n_tables: 2,
            seed: 4,
        };
        let few = evaluate_lsh(base.clone(), 120, 48, 15, 8);
        let mut more_p = base;
        more_p.n_tables = 12;
        let more = evaluate_lsh(more_p, 120, 48, 15, 8);
        assert!(more.recall_at_10 >= few.recall_at_10 - 1e-9);
        assert!(more.candidate_frac >= few.candidate_frac - 1e-9);
    }
}
