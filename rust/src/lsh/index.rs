//! The banded code index: sub-linear candidate generation straight off
//! packed arena words.
//!
//! [`CodeIndex`] slices each sketch's packed bit string into `bands`
//! contiguous bands of `band_bits` bits (whole codes — `band_bits` is a
//! multiple of the code width) and keys a bucket map per band on the
//! band's raw value. No re-hashing happens anywhere: a band is read
//! directly out of the `u64` words the [`crate::scan::CodeArena`]
//! already stores, so indexing a row and probing a query both cost a
//! few shifts per band. This is the classic LSH banding construction
//! (Indyk–Motwani / Datar et al., the paper's Section 1.1 motivation)
//! rebuilt over the serving arena: with `m = band_bits / bits` codes
//! per band and per-code collision probability `P(ρ)`, a true neighbor
//! shares at least one band with probability `1 − (1 − P(ρ)^m)^bands`,
//! while a random row matches a band with probability `≈ P(0)^m` — the
//! recall/cost dial the scheme's collision curve provides.
//!
//! **Multi-probe** widens recall without more bands: besides the exact
//! band value, the query probes the values with one of the `probes`
//! low-order band bits flipped — the adjacent quantizer bins of the
//! band's leading code(s). More probes, more candidates, higher recall;
//! the knob rides on the query, not the index.
//!
//! Buckets store *row indices* into the sealed arena. Rows are remapped
//! wholesale by [`CodeIndex::rebuild`] when compaction moves them; the
//! epoch layer ([`crate::scan::EpochArena`]) owns that lifecycle and
//! keeps the index in lock-step with the sealed arena at every drain.

use std::collections::HashMap;

use crate::coding::supported_width;
use crate::scan::CodeArena;

/// Rows below which an approximate scan should fall back to the exact
/// sweep: probing + rerank overhead beats a sequential pass only once
/// the arena is big enough to prune.
pub const APPROX_MIN_ROWS: usize = 1024;

/// Shape of a banded index: how many bands, how wide, and how many
/// extra low-order-bit probes a query spends per band by default.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IndexConfig {
    /// Contiguous bands keyed per row (each gets its own bucket map).
    pub bands: usize,
    /// Bits per band; a multiple of the code width, at most 64.
    pub band_bits: u32,
    /// Default extra probes per band (low-order single-bit flips of the
    /// band value). 0 = exact-band probing only.
    pub probes: usize,
}

impl IndexConfig {
    /// A default shape for sketches of `k` codes at `bits` per code:
    /// ~12-bit bands (whole codes), at most 32 bands. 12 bits keeps a
    /// random row's per-band match probability around `P(0)^m ≈ 1e-4`
    /// for the paper's 1/2-bit schemes — a few dozen candidates per
    /// band at 10⁵ rows — while `1 − (1 − P(ρ)^m)^bands` stays ≥ 0.99
    /// for ρ ≥ 0.95 neighbors.
    pub fn for_shape(k: usize, bits: u32) -> IndexConfig {
        let bits = supported_width(bits);
        let m = (12 / bits as usize).max(1).min(k.max(1));
        IndexConfig {
            bands: (k / m).clamp(1, 32),
            band_bits: m as u32 * bits,
            probes: 2,
        }
    }

    /// Reject shapes the index cannot serve for sketches of `k` codes
    /// at `bits` per code (width already rounded by the caller).
    pub fn validate(&self, k: usize, bits: u32) -> crate::Result<()> {
        anyhow::ensure!(self.bands >= 1, "index needs at least one band");
        anyhow::ensure!(
            self.band_bits >= bits && self.band_bits <= 64 && self.band_bits % bits == 0,
            "band width {} must be a multiple of the code width {bits} and at most 64",
            self.band_bits
        );
        let codes_per_band = (self.band_bits / bits) as usize;
        anyhow::ensure!(
            self.bands * codes_per_band <= k,
            "{} bands x {} codes/band exceed the sketch width {k}",
            self.bands,
            codes_per_band
        );
        // Probes beyond the band width are clamped at query time, so a
        // sanity cap is all that's needed here.
        anyhow::ensure!(
            self.probes <= 64,
            "{} probes per band is implausible (cap 64)",
            self.probes
        );
        Ok(())
    }
}

/// Read `width` bits starting at absolute bit `lo` out of a packed row.
/// Codes never straddle words (widths divide 64), but a *band* of
/// several codes may; at most two words are touched.
#[inline]
fn band_value(words: &[u64], lo: usize, width: u32) -> u64 {
    let word = lo / 64;
    let off = (lo % 64) as u32;
    let mut v = words[word] >> off;
    if off + width > 64 {
        // off > 0 here, so the shift below is in [1, 63].
        v |= words[word + 1] << (64 - off);
    }
    if width < 64 {
        v &= (1u64 << width) - 1;
    }
    v
}

/// Banded multi-probe index over packed code rows.
///
/// Not internally synchronized: the owner serializes writes against the
/// arena the rows point into (the epoch layer updates it under the
/// sealed write lock it already holds for the drain).
#[derive(Debug)]
pub struct CodeIndex {
    cfg: IndexConfig,
    /// Absolute low bit of each band within a row's bit string.
    band_lo: Vec<usize>,
    /// One bucket map per band: band value → rows holding it.
    buckets: Vec<HashMap<u64, Vec<u32>>>,
    /// Rows currently indexed.
    rows: usize,
}

impl CodeIndex {
    /// An empty index for sketches of `k` codes at `bits` per code
    /// (rounded up to a supported packing width first, like the arena).
    /// Panics on a config [`IndexConfig::validate`] rejects — the
    /// serving layer validates before construction.
    pub fn new(k: usize, bits: u32, cfg: IndexConfig) -> CodeIndex {
        let bits = supported_width(bits);
        cfg.validate(k, bits)
            .expect("index config matches the sketch shape");
        let codes_per_band = (cfg.band_bits / bits) as usize;
        let band_lo = (0..cfg.bands)
            .map(|b| b * codes_per_band * bits as usize)
            .collect();
        CodeIndex {
            cfg,
            band_lo,
            buckets: (0..cfg.bands).map(|_| HashMap::new()).collect(),
            rows: 0,
        }
    }

    pub fn config(&self) -> IndexConfig {
        self.cfg
    }

    /// Rows currently indexed.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Occupied buckets across all bands (a shape/diagnostic gauge).
    pub fn buckets(&self) -> usize {
        self.buckets.iter().map(|m| m.len()).sum()
    }

    /// Largest single bucket across all bands — the skew diagnostic
    /// behind the `crp_collection_index_max_bucket` gauge. A bucket far
    /// above `rows / buckets` means one band value is degenerate (e.g.
    /// all-zero sketches) and candidate sets will balloon toward a
    /// full scan.
    pub fn max_bucket_len(&self) -> usize {
        self.buckets
            .iter()
            .flat_map(|m| m.values())
            .map(Vec::len)
            .max()
            .unwrap_or(0)
    }

    /// Index `row` under every band of its packed words (arena layout,
    /// padding bits zero). The caller must not double-insert a row.
    pub fn insert(&mut self, row: u32, words: &[u64]) {
        for (b, &lo) in self.band_lo.iter().enumerate() {
            let v = band_value(words, lo, self.cfg.band_bits);
            self.buckets[b].entry(v).or_default().push(row);
        }
        self.rows += 1;
    }

    /// Un-index `row`, locating its entries through `words` (the exact
    /// words it was inserted with — i.e. before the arena rewrites or
    /// tombstones the row).
    pub fn remove(&mut self, row: u32, words: &[u64]) {
        for (b, &lo) in self.band_lo.iter().enumerate() {
            let v = band_value(words, lo, self.cfg.band_bits);
            if let Some(bucket) = self.buckets[b].get_mut(&v) {
                if let Some(pos) = bucket.iter().position(|&r| r == row) {
                    bucket.swap_remove(pos);
                    if bucket.is_empty() {
                        self.buckets[b].remove(&v);
                    }
                }
            }
        }
        self.rows = self.rows.saturating_sub(1);
    }

    /// Drop everything, keeping allocated maps.
    pub fn clear(&mut self) {
        for m in &mut self.buckets {
            m.clear();
        }
        self.rows = 0;
    }

    /// Rebuild from scratch over every live row of `arena` — the
    /// compaction path (row ids move wholesale) and the recovery path
    /// (a restored arena image carries no index; this derives it).
    pub fn rebuild(&mut self, arena: &CodeArena) {
        self.clear();
        for row in 0..arena.rows_allocated() as u32 {
            if arena.id_of(row).is_some() {
                self.insert(row, arena.row_words(row));
            }
        }
    }

    /// Candidate rows for a query in arena layout: the union, over all
    /// bands, of the bucket at the query's band value plus the buckets
    /// at that value with one of the `probes` low-order bits flipped.
    /// Sorted ascending and deduplicated. A row whose every band
    /// matches the query (e.g. an exact duplicate) is always returned.
    pub fn candidates(&self, qwords: &[u64], probes: usize) -> Vec<u32> {
        let mut out = Vec::new();
        let flips = probes.min(self.cfg.band_bits as usize);
        for (b, &lo) in self.band_lo.iter().enumerate() {
            let v = band_value(qwords, lo, self.cfg.band_bits);
            if let Some(bucket) = self.buckets[b].get(&v) {
                out.extend_from_slice(bucket);
            }
            for p in 0..flips {
                if let Some(bucket) = self.buckets[b].get(&(v ^ (1u64 << p))) {
                    out.extend_from_slice(bucket);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;
    use crate::mathx::Pcg64;

    fn cfg(bands: usize, band_bits: u32, probes: usize) -> IndexConfig {
        IndexConfig {
            bands,
            band_bits,
            probes,
        }
    }

    #[test]
    fn band_value_reads_straddling_bands() {
        // Two words; a 16-bit band starting at bit 56 spans both.
        let words = [0xABCD_EF01_2345_6789u64, 0x0000_0000_0000_10FEu64];
        assert_eq!(band_value(&words, 0, 16), 0x6789);
        assert_eq!(band_value(&words, 16, 16), 0x2345);
        assert_eq!(band_value(&words, 56, 16), 0xFEAB);
        assert_eq!(band_value(&words, 64, 16), 0x10FE);
        assert_eq!(band_value(&words, 0, 64), words[0]);
    }

    #[test]
    fn for_shape_scales_with_width() {
        let c = IndexConfig::for_shape(256, 2);
        assert_eq!((c.bands, c.band_bits), (32, 12));
        let c = IndexConfig::for_shape(1024, 1);
        assert_eq!((c.bands, c.band_bits), (32, 12));
        let c = IndexConfig::for_shape(64, 4);
        assert_eq!((c.bands, c.band_bits), (21, 12));
        let c = IndexConfig::for_shape(32, 16);
        assert_eq!((c.bands, c.band_bits), (32, 16));
        // Tiny sketches still validate: one band covering what exists.
        let c = IndexConfig::for_shape(4, 2);
        assert_eq!(c.bands, 1);
        c.validate(4, 2).unwrap();
        IndexConfig::for_shape(1, 1).validate(1, 1).unwrap();
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        assert!(cfg(0, 12, 2).validate(64, 2).is_err());
        assert!(cfg(4, 3, 2).validate(64, 2).is_err(), "not a code multiple");
        assert!(cfg(4, 0, 2).validate(64, 2).is_err());
        assert!(cfg(33, 4, 2).validate(64, 2).is_err(), "bands overflow k");
        assert!(cfg(4, 12, 65).validate(64, 2).is_err(), "implausible probes");
        assert!(cfg(4, 12, 13).validate(64, 2).is_ok(), "clamped at query");
        assert!(cfg(8, 12, 2).validate(64, 2).is_ok());
    }

    #[test]
    fn exact_duplicates_are_always_candidates() {
        let mut g = Pcg64::new(7, 0);
        let k = 96;
        let mut idx = CodeIndex::new(k, 2, cfg(8, 12, 0));
        let rows: Vec<_> = (0..200)
            .map(|_| {
                let codes: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
                pack_codes(&codes, 2)
            })
            .collect();
        for (i, p) in rows.iter().enumerate() {
            idx.insert(i as u32, p.words());
        }
        assert_eq!(idx.rows(), 200);
        assert!(idx.buckets() > 0);
        for (i, p) in rows.iter().enumerate() {
            let cands = idx.candidates(p.words(), 0);
            assert!(cands.binary_search(&(i as u32)).is_ok(), "row {i}");
        }
    }

    #[test]
    fn candidates_are_sorted_dedup_and_prune() {
        let mut g = Pcg64::new(9, 1);
        let k = 128;
        let mut idx = CodeIndex::new(k, 1, cfg(10, 12, 2));
        for i in 0..2000u32 {
            let codes: Vec<u16> = (0..k).map(|_| g.next_below(2) as u16).collect();
            idx.insert(i, pack_codes(&codes, 1).words());
        }
        let q: Vec<u16> = (0..k).map(|_| g.next_below(2) as u16).collect();
        let cands = idx.candidates(pack_codes(&q, 1).words(), 2);
        let mut sorted = cands.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(cands, sorted, "sorted + deduplicated");
        // Random 1-bit rows match a 12-bit band w.p. 2^-12; even with
        // 10 bands x 3 probes the candidate set must prune hard.
        assert!(
            cands.len() < 400,
            "no pruning: {} candidates of 2000",
            cands.len()
        );
    }

    #[test]
    fn more_probes_only_add_candidates() {
        let mut g = Pcg64::new(4, 4);
        let k = 64;
        let mut idx = CodeIndex::new(k, 2, cfg(8, 8, 4));
        for i in 0..500u32 {
            let codes: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
            idx.insert(i, pack_codes(&codes, 2).words());
        }
        let q: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
        let qp = pack_codes(&q, 2);
        let mut prev: Vec<u32> = Vec::new();
        for probes in 0..=4 {
            let cur = idx.candidates(qp.words(), probes);
            assert!(
                prev.iter().all(|r| cur.binary_search(r).is_ok()),
                "probes {probes} lost candidates"
            );
            prev = cur;
        }
    }

    #[test]
    fn max_bucket_len_tracks_skew() {
        let k = 96;
        let mut idx = CodeIndex::new(k, 2, cfg(8, 12, 0));
        assert_eq!(idx.max_bucket_len(), 0);
        // Identical rows pile into the same bucket in every band.
        let codes: Vec<u16> = (0..k).map(|i| (i % 4) as u16).collect();
        let p = pack_codes(&codes, 2);
        for row in 0..5u32 {
            idx.insert(row, p.words());
        }
        assert_eq!(idx.max_bucket_len(), 5);
        idx.remove(0, p.words());
        assert_eq!(idx.max_bucket_len(), 4);
        idx.clear();
        assert_eq!(idx.max_bucket_len(), 0);
    }

    #[test]
    fn remove_and_rebuild_track_the_arena() {
        let mut g = Pcg64::new(11, 2);
        let k = 64;
        let mut arena = CodeArena::new(k, 2);
        let mut idx = CodeIndex::new(k, 2, cfg(8, 8, 0));
        let mut packed = Vec::new();
        for i in 0..50 {
            let codes: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
            let p = pack_codes(&codes, 2);
            let row = arena.insert(&format!("id{i}"), &p);
            idx.insert(row, p.words());
            packed.push(p);
        }
        // Removing un-indexes exactly that row.
        idx.remove(3, packed[3].words());
        assert_eq!(idx.rows(), 49);
        assert!(idx
            .candidates(packed[3].words(), 0)
            .binary_search(&3)
            .is_err());
        // Rebuild after compaction matches a fresh index row-for-row.
        arena.remove("id3");
        arena.remove("id40");
        arena.compact();
        idx.rebuild(&arena);
        assert_eq!(idx.rows(), arena.len());
        for row in 0..arena.rows_allocated() as u32 {
            let cands = idx.candidates(arena.row_words(row), 0);
            assert!(cands.binary_search(&row).is_ok(), "row {row}");
        }
    }
}
