//! A single LSH hash table keyed by concatenated codes.

use std::collections::HashMap;

/// One hash table: bucket key = the packed code words of a vector's
/// `k_per_table` projections (hashed through a 64-bit mix).
#[derive(Clone, Debug, Default)]
pub struct LshTable {
    buckets: HashMap<u64, Vec<u32>>,
}

/// Mix a slice of code values into a 64-bit bucket key (FNV-1a over the
/// code stream; collisions across distinct code tuples are harmless —
/// they only add candidates, never lose them).
pub fn bucket_key(codes: &[u16]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &c in codes {
        h ^= c as u64;
        h = h.wrapping_mul(0x100000001b3);
        h ^= (c >> 8) as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl LshTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert item `id` under its codes.
    pub fn insert(&mut self, codes: &[u16], id: u32) {
        self.buckets.entry(bucket_key(codes)).or_default().push(id);
    }

    /// Candidates sharing the query's bucket.
    pub fn probe(&self, codes: &[u16]) -> &[u32] {
        self.buckets
            .get(&bucket_key(codes))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    pub fn n_items(&self) -> usize {
        self.buckets.values().map(|v| v.len()).sum()
    }

    /// Occupancy histogram (bucket sizes), for diagnostics.
    pub fn bucket_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.buckets.values().map(|b| b.len()).collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_probe() {
        let mut t = LshTable::new();
        t.insert(&[1, 2, 3], 7);
        t.insert(&[1, 2, 3], 9);
        t.insert(&[4, 5, 6], 11);
        assert_eq!(t.probe(&[1, 2, 3]), &[7, 9]);
        assert_eq!(t.probe(&[4, 5, 6]), &[11]);
        assert!(t.probe(&[0, 0, 0]).is_empty());
        assert_eq!(t.n_buckets(), 2);
        assert_eq!(t.n_items(), 3);
    }

    #[test]
    fn key_sensitivity() {
        // Different tuples (including order) get different keys.
        assert_ne!(bucket_key(&[1, 2]), bucket_key(&[2, 1]));
        assert_ne!(bucket_key(&[1]), bucket_key(&[1, 0]));
        assert_eq!(bucket_key(&[3, 7]), bucket_key(&[3, 7]));
    }

    #[test]
    fn histogram_sorted_desc() {
        let mut t = LshTable::new();
        for i in 0..5 {
            t.insert(&[1], i);
        }
        t.insert(&[2], 99);
        let h = t.bucket_sizes();
        assert_eq!(h, vec![5, 1]);
    }
}
