//! LSH near-neighbor retrieval over coded projections — the serving
//! stack's sub-linear layer (Section 1.1's motivating application).
//!
//! The seed reproduction kept a standalone multi-table construction
//! (per-sketch `HashMap` tables keyed on FNV-mixed code tuples). This
//! module now centers on [`CodeIndex`]: a **banded multi-probe index**
//! whose buckets key directly on bands of the already-packed arena
//! words — no re-hashing, no second copy of the codes — and store row
//! indices into the columnar [`crate::scan::CodeArena`]. The epoch
//! layer ([`crate::scan::EpochArena`]) maintains it incrementally at
//! every drain and serves `ApproxTopK` by reranking bucket candidates
//! through the same SIMD collision kernels the exact scan uses.
//!
//! [`LshIndex`] remains as the evaluation harness for the paper's
//! scheme comparison (`crp lsh-eval`): the classic `n_tables ×
//! k_per_table` construction, expressed as a [`CodeIndex`] whose bands
//! are exactly the per-table code groups — one band per table. [`eval`]
//! measures recall/candidate-cost per scheme and [`model`] predicts
//! both from the paper's collision probabilities.

pub mod index;
pub mod search;
pub mod eval;
pub mod model;

pub use index::{CodeIndex, IndexConfig, APPROX_MIN_ROWS};
pub use search::{LshIndex, LshParams};
