//! LSH near-neighbor search over coded projections (Section 1.1's
//! motivating application).
//!
//! With `k_per_table` projections and bin width `w`, each table hashes a
//! vector to the concatenation of its codes — `(2·ceil(6/w))^{k_per_table}`
//! logical buckets, stored in a hash map. Multiple independent tables
//! boost recall, exactly the classic LSH construction of Indyk–Motwani /
//! Datar et al. The same machinery runs with any of the four schemes, so
//! the `h_w` vs `h_{w,q}` comparison the paper defers to a tech report
//! can be measured empirically here ([`eval`]).

pub mod table;
pub mod search;
pub mod eval;
pub mod model;

pub use search::{LshIndex, LshParams};
pub use table::LshTable;
