//! Analytical LSH recall model: predicted retrieval probability from the
//! paper's collision probabilities.
//!
//! For a query at similarity ρ to its target, one table of `k` codes
//! collides with probability `P(ρ)^k`, and `L` independent tables
//! retrieve the target with probability `1 − (1 − P(ρ)^k)^L` — the
//! classic LSH amplification, driven entirely by the per-coordinate
//! `P(ρ)` each coding scheme provides. This closes the loop between the
//! theory layer and the measured recall of [`super::eval`].

use crate::theory::SchemeKind;

/// Predicted single-table collision probability at similarity ρ.
pub fn table_collision(scheme: SchemeKind, w: f64, rho: f64, k_per_table: usize) -> f64 {
    scheme
        .collision_probability(rho, w)
        .powi(k_per_table as i32)
}

/// Predicted recall (target retrieved by ≥ 1 of `n_tables`).
pub fn predicted_recall(
    scheme: SchemeKind,
    w: f64,
    rho: f64,
    k_per_table: usize,
    n_tables: usize,
) -> f64 {
    let p = table_collision(scheme, w, rho, k_per_table);
    1.0 - (1.0 - p).powi(n_tables as i32)
}

/// Predicted fraction of a *random* corpus (ρ ≈ 0 pairs) that lands in
/// the query's buckets — the candidate-cost model.
pub fn predicted_candidate_frac(
    scheme: SchemeKind,
    w: f64,
    k_per_table: usize,
    n_tables: usize,
) -> f64 {
    predicted_recall(scheme, w, 0.0, k_per_table, n_tables)
}

/// Solve for the number of tables needed to hit `target_recall` at ρ.
pub fn tables_for_recall(
    scheme: SchemeKind,
    w: f64,
    rho: f64,
    k_per_table: usize,
    target_recall: f64,
) -> usize {
    assert!((0.0..1.0).contains(&target_recall));
    let p = table_collision(scheme, w, rho, k_per_table);
    if p <= 0.0 {
        return usize::MAX;
    }
    if p >= 1.0 {
        return 1;
    }
    ((1.0 - target_recall).ln() / (1.0 - p).ln()).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodingParams, Scheme};
    use crate::lsh::eval::evaluate_lsh_noise;
    use crate::lsh::LshParams;

    #[test]
    fn amplification_monotone() {
        let p1 = predicted_recall(SchemeKind::TwoBit, 0.75, 0.9, 4, 2);
        let p2 = predicted_recall(SchemeKind::TwoBit, 0.75, 0.9, 4, 8);
        assert!(p2 > p1);
        let q1 = predicted_recall(SchemeKind::TwoBit, 0.75, 0.9, 4, 8);
        let q2 = predicted_recall(SchemeKind::TwoBit, 0.75, 0.9, 10, 8);
        assert!(q2 < q1, "longer keys are more selective");
    }

    #[test]
    fn tables_for_recall_solves_inverse() {
        let n = tables_for_recall(SchemeKind::TwoBit, 0.75, 0.9, 4, 0.9);
        let achieved = predicted_recall(SchemeKind::TwoBit, 0.75, 0.9, 4, n);
        assert!(achieved >= 0.9, "{n} tables give {achieved}");
        let under = predicted_recall(SchemeKind::TwoBit, 0.75, 0.9, 4, n - 1);
        assert!(under < 0.9);
    }

    #[test]
    fn model_matches_measured_recall() {
        // The empirical eval at ρ ≈ 0.95 should track the prediction
        // within Monte-Carlo noise — theory ↔ system closure.
        let (kpt, tables) = (4usize, 8usize);
        let dim = 48;
        let noise = 0.05;
        let rho = 1.0 / (1.0 + dim as f64 * noise * noise).sqrt();
        let predicted = predicted_recall(SchemeKind::TwoBit, 0.75, rho, kpt, tables);
        let params = LshParams {
            coding: CodingParams::new(Scheme::TwoBit, 0.75),
            k_per_table: kpt,
            n_tables: tables,
            seed: 5,
        };
        let measured = evaluate_lsh_noise(params, 200, dim, 60, 9, noise).recall_at_10;
        assert!(
            (measured - predicted).abs() < 0.15,
            "measured {measured} vs predicted {predicted}"
        );
    }

    #[test]
    fn candidate_frac_model_reasonable() {
        let f = predicted_candidate_frac(SchemeKind::OneBit, 0.0, 8, 4);
        // 1-bit keys of length 8: random pair collides 0.5^8 per table.
        let want = 1.0 - (1.0 - 0.5f64.powi(8)).powi(4);
        assert!((f - want).abs() < 1e-12);
    }
}
