//! Multi-table LSH index over coded random projections.

use super::table::LshTable;
use crate::coding::CodingParams;
use crate::projection::{ProjectionConfig, Projector};

/// Index parameters.
#[derive(Clone, Debug)]
pub struct LshParams {
    /// Coding scheme + bin width used for bucketing.
    pub coding: CodingParams,
    /// Projections concatenated per table.
    pub k_per_table: usize,
    /// Number of independent tables.
    pub n_tables: usize,
    /// Seed for the projection matrices (table `t` uses `seed + t`).
    pub seed: u64,
}

impl Default for LshParams {
    fn default() -> Self {
        LshParams {
            coding: CodingParams::new(crate::coding::Scheme::TwoBit, 0.75),
            k_per_table: 8,
            n_tables: 8,
            seed: 42,
        }
    }
}

/// A multi-table LSH index storing dense vectors.
pub struct LshIndex {
    pub params: LshParams,
    projectors: Vec<Projector>,
    tables: Vec<LshTable>,
    /// Stored vectors (dense), for exact re-ranking of candidates.
    data: Vec<Vec<f32>>,
}

impl LshIndex {
    pub fn new(params: LshParams) -> Self {
        let projectors = (0..params.n_tables)
            .map(|t| {
                Projector::new_cpu(ProjectionConfig {
                    k: params.k_per_table,
                    seed: params.seed + t as u64,
                    ..Default::default()
                })
            })
            .collect();
        let tables = (0..params.n_tables).map(|_| LshTable::new()).collect();
        LshIndex {
            params,
            projectors,
            tables,
            data: Vec::new(),
        }
    }

    fn codes_for(&self, t: usize, v: &[f32]) -> Vec<u16> {
        // The paper's analysis assumes unit-norm inputs (projected values
        // marginally N(0,1)); normalize so queries with different norms
        // hash consistently (LSH for cosine similarity).
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let x = if norm > 0.0 && (norm - 1.0).abs() > 1e-6 {
            let scaled: Vec<f32> = v.iter().map(|x| x / norm).collect();
            self.projectors[t].project_dense(&scaled)
        } else {
            self.projectors[t].project_dense(v)
        };
        self.params.coding.encode(&x)
    }

    /// Insert a vector; returns its id.
    pub fn insert(&mut self, v: &[f32]) -> u32 {
        let id = self.data.len() as u32;
        for t in 0..self.params.n_tables {
            let codes = self.codes_for(t, v);
            self.tables[t].insert(&codes, id);
        }
        self.data.push(v.to_vec());
        id
    }

    /// Number of stored vectors.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Candidate ids across all tables (deduplicated), plus the number
    /// of bucket probes performed.
    pub fn candidates(&self, q: &[f32]) -> (Vec<u32>, usize) {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for t in 0..self.params.n_tables {
            let codes = self.codes_for(t, q);
            for &id in self.tables[t].probe(&codes) {
                if seen.insert(id) {
                    out.push(id);
                }
            }
        }
        (out, self.params.n_tables)
    }

    /// Top-`n` near neighbors by exact cosine over the candidate set.
    /// Returns `(id, similarity)` sorted descending.
    pub fn query(&self, q: &[f32], n: usize) -> Vec<(u32, f64)> {
        let (cands, _) = self.candidates(q);
        let mut scored: Vec<(u32, f64)> = cands
            .into_iter()
            .map(|id| (id, cosine(q, &self.data[id as usize])))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored.truncate(n);
        scored
    }

    /// Exact (brute-force) top-`n`, for recall evaluation.
    pub fn brute_force(&self, q: &[f32], n: usize) -> Vec<(u32, f64)> {
        let mut scored: Vec<(u32, f64)> = self
            .data
            .iter()
            .enumerate()
            .map(|(id, v)| (id as u32, cosine(q, v)))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored.truncate(n);
        scored
    }
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += (x as f64) * (x as f64);
        nb += (y as f64) * (y as f64);
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::pairs::unit_pair_with_rho;
    use crate::mathx::NormalSampler;

    fn random_unit(d: usize, seed: u64) -> Vec<f32> {
        let mut ns = NormalSampler::new(seed, 1);
        let mut v: Vec<f32> = (0..d).map(|_| ns.next() as f32).collect();
        let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        for x in &mut v {
            *x /= n;
        }
        v
    }

    #[test]
    fn finds_exact_duplicate() {
        let mut idx = LshIndex::new(LshParams::default());
        let d = 64;
        for s in 0..50 {
            idx.insert(&random_unit(d, s));
        }
        let target = random_unit(d, 7);
        let hits = idx.query(&target, 1);
        assert_eq!(hits[0].0, 7);
        assert!(hits[0].1 > 0.999);
    }

    #[test]
    fn finds_near_neighbor_with_high_probability() {
        let mut idx = LshIndex::new(LshParams {
            n_tables: 12,
            k_per_table: 6,
            ..Default::default()
        });
        let d = 64;
        for s in 0..200 {
            idx.insert(&random_unit(d, 1000 + s));
        }
        // Plant a pair with ρ = 0.95 and query with its twin.
        let (u, v) = unit_pair_with_rho(d, 0.95, 5);
        let planted = idx.insert(&u);
        let hits = idx.query(&v, 3);
        assert!(
            hits.iter().any(|&(id, _)| id == planted),
            "planted neighbor not found: {hits:?}"
        );
    }

    #[test]
    fn candidates_fraction_small_for_random_queries() {
        // LSH must prune: a random query should touch far fewer
        // candidates than the corpus.
        let mut idx = LshIndex::new(LshParams {
            n_tables: 4,
            k_per_table: 10,
            ..Default::default()
        });
        let d = 64;
        for s in 0..300 {
            idx.insert(&random_unit(d, 2000 + s));
        }
        let q = random_unit(d, 1);
        let (cands, _) = idx.candidates(&q);
        assert!(
            cands.len() < 150,
            "no pruning: {} candidates of 300",
            cands.len()
        );
    }

    #[test]
    fn brute_force_is_ground_truth() {
        let mut idx = LshIndex::new(LshParams::default());
        let d = 32;
        for s in 0..20 {
            idx.insert(&random_unit(d, 3000 + s));
        }
        let q = random_unit(d, 3005);
        let bf = idx.brute_force(&q, 20);
        assert_eq!(bf.len(), 20);
        assert_eq!(bf[0].0, 5); // itself
        for w in bf.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }
}
