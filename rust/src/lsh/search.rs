//! The classic multi-table LSH construction, expressed over the banded
//! [`CodeIndex`]: table `t` is band `t` — its `k_per_table` packed codes
//! read straight out of the sketch's words. Candidates are exactly the
//! vectors sharing at least one full table key with the query (the old
//! hashed-tuple tables matched the same set, plus spurious 64-bit hash
//! collisions; band keys are exact, so those are gone).

use super::index::{CodeIndex, IndexConfig};
use crate::coding::{pack_codes, CodingParams};
use crate::estimator::CollisionEstimator;
use crate::projection::{ProjectionConfig, Projector};
use crate::scan::kernels::collisions_words;
use crate::scan::{CodeArena, TopK};

/// Index parameters.
#[derive(Clone, Debug)]
pub struct LshParams {
    /// Coding scheme + bin width used for bucketing.
    pub coding: CodingParams,
    /// Projections concatenated per table.
    pub k_per_table: usize,
    /// Number of independent tables.
    pub n_tables: usize,
    /// Seed for the projection matrices (table `t` uses `seed + t`).
    pub seed: u64,
}

impl Default for LshParams {
    fn default() -> Self {
        LshParams {
            coding: CodingParams::new(crate::coding::Scheme::TwoBit, 0.75),
            k_per_table: 8,
            n_tables: 8,
            seed: 42,
        }
    }
}

/// A multi-table LSH index storing dense vectors.
pub struct LshIndex {
    pub params: LshParams,
    projectors: Vec<Projector>,
    /// Banded index over the packed sketches: one band per table.
    index: CodeIndex,
    /// Stored vectors (dense), for exact re-ranking of candidates.
    data: Vec<Vec<f32>>,
    /// Full-resolution packed sketches — every table's codes
    /// concatenated — in a columnar arena (row = insertion id), for
    /// code-only candidate re-ranking through the scan kernels.
    sketches: CodeArena,
    /// Collision-rate inverter over the `n_tables · k_per_table`
    /// concatenated projections.
    est: CollisionEstimator,
}

impl LshIndex {
    pub fn new(params: LshParams) -> Self {
        let projectors = (0..params.n_tables)
            .map(|t| {
                Projector::new_cpu(ProjectionConfig {
                    k: params.k_per_table,
                    seed: params.seed + t as u64,
                    ..Default::default()
                })
            })
            .collect();
        let sketches = CodeArena::new(
            params.n_tables * params.k_per_table,
            params.coding.bits_per_code(),
        );
        let band_bits = params.k_per_table as u32 * sketches.bits();
        assert!(
            band_bits <= 64,
            "table key of {} codes x {} bit(s) exceeds a 64-bit band \
             (shrink --k-per-table or the code width)",
            params.k_per_table,
            sketches.bits()
        );
        let index = CodeIndex::new(
            sketches.k(),
            sketches.bits(),
            IndexConfig {
                bands: params.n_tables,
                band_bits,
                probes: 0,
            },
        );
        let est = CollisionEstimator::new(params.coding.clone());
        LshIndex {
            params,
            projectors,
            index,
            data: Vec::new(),
            sketches,
            est,
        }
    }

    fn codes_for(&self, t: usize, v: &[f32]) -> Vec<u16> {
        // The paper's analysis assumes unit-norm inputs (projected values
        // marginally N(0,1)); normalize so queries with different norms
        // hash consistently (LSH for cosine similarity).
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let x = if norm > 0.0 && (norm - 1.0).abs() > 1e-6 {
            let scaled: Vec<f32> = v.iter().map(|x| x / norm).collect();
            self.projectors[t].project_dense(&scaled)
        } else {
            self.projectors[t].project_dense(v)
        };
        self.params.coding.encode(&x)
    }

    /// All tables' codes for `v`, concatenated in table order — the
    /// full-resolution sketch whose bands are the table keys.
    fn all_codes(&self, v: &[f32]) -> Vec<u16> {
        let mut all = Vec::with_capacity(self.params.n_tables * self.params.k_per_table);
        for t in 0..self.params.n_tables {
            all.extend(self.codes_for(t, v));
        }
        all
    }

    /// Insert a vector; returns its id.
    pub fn insert(&mut self, v: &[f32]) -> u32 {
        let id = self.data.len() as u32;
        let all = self.all_codes(v);
        let sketch = pack_codes(&all, self.params.coding.bits_per_code());
        let row = self.sketches.insert(&format!("{id:08}"), &sketch);
        debug_assert_eq!(row, id, "insertion order is the id");
        self.index.insert(row, sketch.words());
        self.data.push(v.to_vec());
        id
    }

    /// Number of stored vectors.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Candidate ids across all tables (sorted, deduplicated), plus the
    /// number of bucket probes performed.
    pub fn candidates(&self, q: &[f32]) -> (Vec<u32>, usize) {
        let all = self.all_codes(q);
        let query = pack_codes(&all, self.params.coding.bits_per_code());
        (
            self.index.candidates(query.words(), 0),
            self.params.n_tables,
        )
    }

    /// Top-`n` near neighbors by exact cosine over the candidate set.
    /// Returns `(id, similarity)` sorted descending.
    pub fn query(&self, q: &[f32], n: usize) -> Vec<(u32, f64)> {
        let (cands, _) = self.candidates(q);
        let mut scored: Vec<(u32, f64)> = cands
            .into_iter()
            .map(|id| (id, cosine(q, &self.data[id as usize])))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored.truncate(n);
        scored
    }

    /// Top-`n` near neighbors by **coded** re-ranking: candidates from
    /// the banded index, scored by collision count between
    /// full-resolution packed sketches (scan kernels over the arena
    /// rows) and inverted to ρ̂ — no dense vector is touched after
    /// insert. Returns `(id, rho_hat)` ordered `(collisions desc, id asc)`.
    pub fn query_coded(&self, q: &[f32], n: usize) -> Vec<(u32, f64)> {
        use std::fmt::Write as _;
        let rank_k = self.params.n_tables * self.params.k_per_table;
        let all = self.all_codes(q);
        let query = pack_codes(&all, self.params.coding.bits_per_code());
        let cands = self.index.candidates(query.words(), 0);
        let mut top = TopK::new(n);
        // One reused buffer for the zero-padded tie-break key; `offer`
        // clones it only for candidates that enter the selection.
        let mut row_id = String::with_capacity(8);
        for id in cands {
            row_id.clear();
            let _ = write!(row_id, "{id:08}");
            let c = collisions_words(
                self.sketches.bits(),
                rank_k,
                query.words(),
                self.sketches.row_words(id),
            );
            top.offer(id, &row_id, c);
        }
        top.into_sorted()
            .into_iter()
            .map(|e| (e.row, self.est.estimate_from_count(e.collisions, rank_k)))
            .collect()
    }

    /// Exact (brute-force) top-`n`, for recall evaluation.
    pub fn brute_force(&self, q: &[f32], n: usize) -> Vec<(u32, f64)> {
        let mut scored: Vec<(u32, f64)> = self
            .data
            .iter()
            .enumerate()
            .map(|(id, v)| (id as u32, cosine(q, v)))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored.truncate(n);
        scored
    }
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += (x as f64) * (x as f64);
        nb += (y as f64) * (y as f64);
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::pairs::unit_pair_with_rho;
    use crate::mathx::NormalSampler;

    fn random_unit(d: usize, seed: u64) -> Vec<f32> {
        let mut ns = NormalSampler::new(seed, 1);
        let mut v: Vec<f32> = (0..d).map(|_| ns.next() as f32).collect();
        let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        for x in &mut v {
            *x /= n;
        }
        v
    }

    #[test]
    fn finds_exact_duplicate() {
        let mut idx = LshIndex::new(LshParams::default());
        let d = 64;
        for s in 0..50 {
            idx.insert(&random_unit(d, s));
        }
        let target = random_unit(d, 7);
        let hits = idx.query(&target, 1);
        assert_eq!(hits[0].0, 7);
        assert!(hits[0].1 > 0.999);
    }

    #[test]
    fn finds_near_neighbor_with_high_probability() {
        let mut idx = LshIndex::new(LshParams {
            n_tables: 12,
            k_per_table: 6,
            ..Default::default()
        });
        let d = 64;
        for s in 0..200 {
            idx.insert(&random_unit(d, 1000 + s));
        }
        // Plant a pair with ρ = 0.95 and query with its twin.
        let (u, v) = unit_pair_with_rho(d, 0.95, 5);
        let planted = idx.insert(&u);
        let hits = idx.query(&v, 3);
        assert!(
            hits.iter().any(|&(id, _)| id == planted),
            "planted neighbor not found: {hits:?}"
        );
    }

    #[test]
    fn candidates_fraction_small_for_random_queries() {
        // LSH must prune: a random query should touch far fewer
        // candidates than the corpus.
        let mut idx = LshIndex::new(LshParams {
            n_tables: 4,
            k_per_table: 10,
            ..Default::default()
        });
        let d = 64;
        for s in 0..300 {
            idx.insert(&random_unit(d, 2000 + s));
        }
        let q = random_unit(d, 1);
        let (cands, _) = idx.candidates(&q);
        assert!(
            cands.len() < 150,
            "no pruning: {} candidates of 300",
            cands.len()
        );
    }

    #[test]
    fn coded_rerank_finds_exact_duplicate() {
        let mut idx = LshIndex::new(LshParams::default());
        let d = 64;
        for s in 0..60 {
            idx.insert(&random_unit(d, 4000 + s));
        }
        let target = random_unit(d, 4011);
        let hits = idx.query_coded(&target, 3);
        assert_eq!(hits[0].0, 11);
        assert!(hits[0].1 > 0.95, "rho {}", hits[0].1);
    }

    #[test]
    fn coded_rerank_matches_bruteforce_over_candidates() {
        let mut idx = LshIndex::new(LshParams {
            n_tables: 6,
            k_per_table: 5,
            ..Default::default()
        });
        let d = 48;
        for s in 0..120 {
            idx.insert(&random_unit(d, 5000 + s));
        }
        let rank_k = idx.params.n_tables * idx.params.k_per_table;
        for qs in 0..5 {
            let q = random_unit(d, 5000 + qs * 17);
            let got = idx.query_coded(&q, 8);
            // Brute force over the same candidate set with the packed
            // per-pair counter — identical ranking and identical ρ̂.
            let qcodes = idx.all_codes(&q);
            let query = pack_codes(&qcodes, idx.params.coding.bits_per_code());
            let (cands, _) = idx.candidates(&q);
            let mut want: Vec<(u32, usize)> = cands
                .into_iter()
                .map(|id| {
                    let stored = idx.sketches.get(&format!("{id:08}")).unwrap();
                    (id, crate::coding::collision_count_packed(&query, &stored))
                })
                .collect();
            want.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
            want.truncate(8);
            assert_eq!(got.len(), want.len(), "query {qs}");
            for ((gid, grho), (wid, wc)) in got.iter().zip(&want) {
                assert_eq!(gid, wid, "query {qs}");
                assert_eq!(*grho, idx.est.estimate_from_count(*wc, rank_k));
            }
        }
    }

    #[test]
    fn brute_force_is_ground_truth() {
        let mut idx = LshIndex::new(LshParams::default());
        let d = 32;
        for s in 0..20 {
            idx.insert(&random_unit(d, 3000 + s));
        }
        let q = random_unit(d, 3005);
        let bf = idx.brute_force(&q, 20);
        assert_eq!(bf.len(), 20);
        assert_eq!(bf[0].0, 5); // itself
        for w in bf.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }
}
