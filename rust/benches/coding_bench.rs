//! Hot-path microbenchmarks: encoding, packing, collision counting —
//! the per-sketch operations on the serving path.

#[path = "harness/mod.rs"]
mod harness;

use crp::coding::{
    collision_count, collision_count_packed, pack_codes, CodingParams, Scheme,
};
use crp::data::pairs::bivariate_normal_batch;

fn main() {
    let mut b = harness::Bench::new();
    let k = 4096;
    let (x, y) = bivariate_normal_batch(k, 0.7, 1);

    for (scheme, w) in [
        (Scheme::Uniform, 0.75),
        (Scheme::WindowOffset, 0.75),
        (Scheme::TwoBit, 0.75),
        (Scheme::OneBit, 0.0),
    ] {
        let params = CodingParams::new(scheme, w);
        let offsets = match scheme {
            Scheme::WindowOffset => Some(params.offsets(k)),
            _ => None,
        };
        let mut out = vec![0u16; k];
        b.run(
            &format!("encode/{}/k{k}", scheme.label()),
            k as u64,
            || {
                params.encode_into(&x, offsets.as_deref(), &mut out);
                std::hint::black_box(&out);
            },
        );
    }

    let params = CodingParams::new(Scheme::TwoBit, 0.75);
    let cu = params.encode(&x);
    let cv = params.encode(&y);
    b.run("pack/2bit/k4096", k as u64, || {
        std::hint::black_box(pack_codes(&cu, 2));
    });

    let pu = pack_codes(&cu, 2);
    let pv = pack_codes(&cv, 2);
    b.run("collision/scalar/k4096", k as u64, || {
        std::hint::black_box(collision_count(&cu, &cv));
    });
    b.run("collision/packed-2bit/k4096", k as u64, || {
        std::hint::black_box(collision_count_packed(&pu, &pv));
    });

    let p1 = CodingParams::new(Scheme::OneBit, 0.0);
    let b1u = pack_codes(&p1.encode(&x), 1);
    let b1v = pack_codes(&p1.encode(&y), 1);
    b.run("collision/packed-1bit/k4096", k as u64, || {
        std::hint::black_box(collision_count_packed(&b1u, &b1v));
    });

    // One-hot expansion (Section 6 feature building).
    b.run("expand/2bit/k4096", k as u64, || {
        std::hint::black_box(crp::coding::expand_to_sparse(&cu, 4));
    });

    b.finish();
}
