//! Coordinator end-to-end benchmark: request RTT and throughput through
//! the full TCP → router → batcher → projector → store path.

#[path = "harness/mod.rs"]
mod harness;

use crp::coordinator::server::{serve, ServerConfig, ServerMode};
use crp::coordinator::SketchClient;
use crp::projection::{ProjectionConfig, Projector};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let mut b = harness::Bench::new();
    let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
        k: 256,
        seed: 1,
        ..Default::default()
    }));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = serve(projector, cfg, Some(tx));
    });
    let addr = rx
        .recv()
        .expect("server thread exited before reporting its bound address")
        .to_string();

    let mut client = SketchClient::connect(&addr).unwrap();
    let dim = 256;
    let mut g = crp::mathx::Pcg64::new(5, 0);
    let v: Vec<f32> = (0..dim).map(|_| g.next_f64() as f32 - 0.5).collect();

    // Single-connection register RTT (includes the 2ms batching window
    // when traffic is sparse — this is the latency a lone client sees).
    let mut i = 0u64;
    b.run("serve/register-rtt/dim256", 1, || {
        i += 1;
        client.register(&format!("bench-{i}"), v.clone()).unwrap();
    });

    client.register("q", v.clone()).unwrap();
    b.run("serve/estimate-rtt", 1, || {
        std::hint::black_box(client.estimate("q", "bench-1").unwrap());
    });

    b.run("serve/knn-10-rtt", 1, || {
        std::hint::black_box(client.knn(v.clone(), 10).unwrap());
    });

    // Concurrent throughput: 8 closed-loop clients.
    let n_clients = 8;
    let per = 200;
    let t = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut cl = SketchClient::connect(&addr).unwrap();
            let mut g = crp::mathx::Pcg64::new(100 + c, 0);
            for i in 0..per {
                let v: Vec<f32> = (0..256).map(|_| g.next_f64() as f32).collect();
                cl.register(&format!("t{c}-{i}"), v).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let total = t.elapsed().as_secs_f64();
    println!(
        "{:<52} {:>14.0} req/s ({} clients x {} registers in {:.2}s)",
        "serve/register-throughput/8conn",
        (n_clients * per) as f64 / total,
        n_clients,
        per,
        total
    );

    let mut cl = SketchClient::connect(&addr).unwrap();
    let stats = cl.stats().unwrap();
    println!(
        "{:<52} {:>14.1} vectors/batch",
        "serve/mean-batch-size", stats.mean_batch_size
    );

    // Replicated reads: a durable primary, an in-memory replica tailing
    // its WAL over the wire. Measures read RTT through a caught-up
    // replica against the same query on the primary — the cost (it
    // should be none) of moving read traffic off the primary.
    {
        let dir = std::env::temp_dir().join(format!("crp_bench_repl_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let spawn = |cfg: ServerConfig| -> String {
            let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
                k: 256,
                seed: 1,
                ..Default::default()
            }));
            let (tx, rx) = std::sync::mpsc::channel();
            std::thread::spawn(move || {
                let _ = serve(projector, cfg, Some(tx));
            });
            rx.recv().expect("server died before binding").to_string()
        };
        let p_addr = spawn(ServerConfig {
            addr: "127.0.0.1:0".into(),
            durability: Some(crp::coordinator::DurabilityConfig {
                snapshot: dir.join("snapshot.bin"),
                wal_dir: dir.join("wal"),
                checkpoint_every: 0,
                fsync: crp::coordinator::FsyncPolicy::Os,
            }),
            ..Default::default()
        });
        let mut p = SketchClient::connect(&p_addr).unwrap();
        let rows = 2000usize;
        let ids: Vec<String> = (0..rows).map(|i| format!("r{i:05}")).collect();
        let vectors: Vec<Vec<f32>> = (0..rows)
            .map(|_| (0..dim).map(|_| g.next_f64() as f32 - 0.5).collect())
            .collect();
        p.register_batch_in(None, ids, vectors).unwrap();

        let r_addr = spawn(ServerConfig {
            addr: "127.0.0.1:0".into(),
            replicate_from: Some(p_addr.clone()),
            repl_poll: std::time::Duration::from_millis(10),
            repl_backoff_min: std::time::Duration::from_millis(10),
            repl_backoff_max: std::time::Duration::from_millis(200),
            ..Default::default()
        });
        let mut r = SketchClient::connect(&r_addr).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        loop {
            let st = r.stats_detailed().unwrap();
            let caught = st.per_collection.iter().any(|c| c.rows == rows as u64)
                && st.replication.as_ref().is_some_and(|x| x.lag_bytes == 0);
            if caught {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "replica never caught up"
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }

        b.run("serve/primary-knn-10-rtt/2k-rows", 1, || {
            std::hint::black_box(p.knn(v.clone(), 10).unwrap());
        });
        b.run("serve/replica-knn-10-rtt/2k-rows", 1, || {
            std::hint::black_box(r.knn(v.clone(), 10).unwrap());
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    // Ablation: batching policy (max_batch × idle_flush) vs throughput
    // under 8 closed-loop clients — the design-choice sweep behind the
    // coordinator defaults (DESIGN.md §7 / EXPERIMENTS.md §Perf).
    println!("
batching-policy ablation (8 closed-loop clients, dim 256):");
    println!(
        "{:<16} {:>12} {:>12} {:>12}",
        "max_batch", "idle_us", "req/s", "mean_batch"
    );
    for &(max_batch, idle_us) in &[
        (1usize, 0u64),
        (16, 150),
        (64, 150),
        (64, 2000), // no early flush (idle == deadline)
        (256, 150),
    ] {
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 256,
            seed: 1,
            ..Default::default()
        }));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            batcher: crp::coordinator::BatcherConfig {
                max_batch,
                max_delay: std::time::Duration::from_millis(2),
                idle_flush: std::time::Duration::from_micros(idle_us.max(1)),
            },
            ..Default::default()
        };
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let _ = serve(projector, cfg, Some(tx));
        });
        let addr = rx
            .recv()
            .expect("server thread exited before reporting its bound address")
            .to_string();
        let n_clients = 8;
        let per = 150;
        let t = std::time::Instant::now();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut cl = SketchClient::connect(&addr).unwrap();
                let mut g = crp::mathx::Pcg64::new(200 + c, 0);
                for i in 0..per {
                    let v: Vec<f32> = (0..256).map(|_| g.next_f64() as f32).collect();
                    cl.register(&format!("t{c}-{i}"), v).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let total = t.elapsed().as_secs_f64();
        let mut cl = SketchClient::connect(&addr).unwrap();
        let stats = cl.stats().unwrap();
        println!(
            "{:<16} {:>12} {:>12.0} {:>12.1}",
            max_batch,
            idle_us,
            (n_clients * per) as f64 / total,
            stats.mean_batch_size
        );
    }

    // Connection scaling: ping RTT percentiles at a fixed offered load
    // while N open connections are held, per front-end layout. Thread
    // mode may degrade or refuse outright at the top end (one OS thread
    // per connection); the reactor layouts are expected to stay flat,
    // and the sharded layout to pull ahead once one loop saturates —
    // all outcomes are recorded. Eight concurrent closed-loop client
    // threads drive the load so multi-loop parallelism can show.
    {
        let raised = crp::coordinator::reactor::raise_nofile_limit();
        println!("\nconnection scaling (held connections vs ping RTT; nofile limit {raised:?}):");
        println!(
            "{:<12} {:>8} {:>12} {:>12} {:>12}",
            "layout", "conns", "req/s", "p50_us", "p99_us"
        );
        // (label, mode, reactor_threads, reactor_workers)
        let layouts: &[(&str, ServerMode, usize, usize)] = &[
            ("threads", ServerMode::Threads, 0, 0),
            ("reactor1-w0", ServerMode::Reactor, 0, 0),
            ("reactor1-w2", ServerMode::Reactor, 0, 2),
            ("reactor4-w0", ServerMode::Reactor, 4, 0),
            ("reactor4-w2", ServerMode::Reactor, 4, 2),
        ];
        let mut results: Vec<(&str, usize, f64)> = Vec::new();
        for &(label, mode, threads, workers) in layouts {
            for &conns in &[64usize, 512, 4096, 16384] {
                match conn_scale_run(mode, threads, workers, conns) {
                    Ok((rps, p50, p99)) => {
                        println!(
                            "{:<12} {:>8} {:>12.0} {:>12} {:>12}",
                            label,
                            conns,
                            rps,
                            p50 / 1000,
                            p99 / 1000
                        );
                        let name = format!("serve/conn-scale/{label}/{conns}");
                        b.record(&format!("{name}/p50"), p50 as f64, rps);
                        b.record(&format!("{name}/p99"), p99 as f64, rps);
                        results.push((label, conns, rps));
                    }
                    Err(e) => println!("{:<12} {:>8}  failed: {e}", label, conns),
                }
            }
        }
        // Scaling headline: sharded vs single-loop throughput at the
        // largest connection count both layouts completed.
        let best = |label: &str| {
            results
                .iter()
                .filter(|(l, _, _)| *l == label)
                .max_by_key(|(_, conns, _)| *conns)
                .copied()
        };
        if let (Some((_, c4, r4)), Some((_, c1, r1))) = (best("reactor4-w0"), best("reactor1-w0"))
        {
            let conns = c4.min(c1);
            let at = |label: &str, conns: usize| {
                results
                    .iter()
                    .find(|(l, c, _)| *l == label && *c == conns)
                    .map(|&(_, _, r)| r)
            };
            if let (Some(r4), Some(r1)) = (at("reactor4-w0", conns), at("reactor1-w0", conns)) {
                println!(
                    "\nscaling headline: reactor x4 {:.0} req/s vs x1 {:.0} req/s \
                     at {} conns ({:.2}x)",
                    r4,
                    r1,
                    conns,
                    r4 / r1
                );
            } else {
                println!(
                    "\nscaling headline: reactor x4 {r4:.0} req/s @ {c4} conns vs \
                     x1 {r1:.0} req/s @ {c1} conns (no shared conn count)"
                );
            }
        }
    }

    b.finish_json(std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_scan.json"
    )));
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    sorted[((sorted.len() as f64 - 1.0) * p).round() as usize]
}

/// Hold `conns` open connections against a fresh server laid out as
/// `(mode, reactor_threads, workers)` and drive a fixed load of ping
/// round trips from 8 concurrent closed-loop client threads, each
/// cycling its own share of the pool. Returns (req/s, p50 ns, p99 ns);
/// any refusal (accept thread spawn, fd exhaustion, connection cap)
/// surfaces as the error string.
fn conn_scale_run(
    mode: ServerMode,
    reactor_threads: usize,
    workers: usize,
    conns: usize,
) -> Result<(f64, u64, u64), String> {
    use crp::coordinator::protocol::{self, Request};

    let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
        k: 256,
        seed: 1,
        ..Default::default()
    }));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        server_mode: mode,
        reactor_threads,
        reactor_workers: workers,
        max_conns: conns + 8,
        ..Default::default()
    };
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = serve(projector, cfg, Some(tx));
    });
    let addr = rx
        .recv()
        .map_err(|_| "server died before binding".to_string())?
        .to_string();

    let mut pool = Vec::with_capacity(conns);
    for i in 0..conns {
        let s = TcpStream::connect(&addr).map_err(|e| format!("connect {i}/{conns}: {e}"))?;
        s.set_nodelay(true).map_err(|e| e.to_string())?;
        s.set_read_timeout(Some(std::time::Duration::from_secs(10)))
            .map_err(|e| e.to_string())?;
        pool.push(s);
    }

    // Split the pool across the drivers; each driver round-robins its
    // own share so every held connection sees traffic.
    let drivers = 8usize.min(conns);
    let per_driver_conns = conns / drivers;
    let total = conns.max(8000);
    let per_driver_reqs = total / drivers;
    let mut handles = Vec::with_capacity(drivers);
    let t0 = Instant::now();
    for _ in 0..drivers {
        let share: Vec<TcpStream> = pool.drain(..per_driver_conns.min(pool.len())).collect();
        handles.push(std::thread::spawn(move || -> Result<Vec<u64>, String> {
            let ping = Request::Ping.encode();
            let mut share = share;
            let mut lat = Vec::with_capacity(per_driver_reqs);
            let mut frame = Vec::new();
            for i in 0..per_driver_reqs {
                let s = &mut share[i % share.len()];
                let t = Instant::now();
                protocol::write_frame(s, &ping).map_err(|e| format!("write: {e}"))?;
                protocol::read_frame_into(s, &mut frame).map_err(|e| format!("read: {e}"))?;
                lat.push(t.elapsed().as_nanos() as u64);
            }
            Ok(lat)
        }));
    }
    let mut lat = Vec::with_capacity(total);
    for h in handles {
        lat.extend(h.join().map_err(|_| "driver panicked".to_string())??);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    lat.sort_unstable();
    Ok((
        lat.len() as f64 / elapsed,
        percentile(&lat, 0.50),
        percentile(&lat, 0.99),
    ))
}
