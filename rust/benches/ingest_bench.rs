//! Ingest-path throughput for the epoch-buffered store: steady-state
//! overwrite puts (drains amortized at the epoch threshold), the fused
//! bulk `put_rows` path, put latency while a scanner floods the read
//! side — the case the seed design serialized behind the arena write
//! lock — and the sparse projection front-end (dense GEMM vs the
//! O(nnz·k) gather kernel vs the sign-sparse add/sub matrix at
//! d = 2^20). Results merge into the repo-root `BENCH_scan.json`
//! alongside `scan_bench`'s numbers.

#[path = "harness/mod.rs"]
mod harness;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crp::coding::PackedCodes;
use crp::coordinator::SketchStore;
use crp::mathx::Pcg64;

/// Random one-bit sketches are random words (padding bits zeroed).
fn random_sketch(g: &mut Pcg64, k: usize, bits: u32) -> PackedCodes {
    let per_word = (64 / bits) as usize;
    let n_words = k.div_ceil(per_word);
    let mut words: Vec<u64> = (0..n_words).map(|_| g.next_u64()).collect();
    let rem = k % per_word;
    if rem > 0 {
        words[n_words - 1] &= (1u64 << (rem as u32 * bits)) - 1;
    }
    PackedCodes::from_words(bits, k, words)
}

fn main() {
    let mut b = harness::Bench::new();
    let (k, bits) = (1024usize, 1u32);
    let n = 50_000usize;
    let mut g = Pcg64::new(11, 0);
    let sketches: Vec<PackedCodes> = (0..n).map(|_| random_sketch(&mut g, k, bits)).collect();
    let ids: Vec<String> = (0..n).map(|i| format!("{i:07}")).collect();

    // Steady-state overwrite ingest: the store is pre-seeded, so every
    // put masks a sealed row and lands a pending one; drains fire at the
    // default threshold and are included in the measurement.
    let store = SketchStore::with_arena(k, bits);
    for (id, s) in ids.iter().zip(&sketches) {
        store.put(id.clone(), s.clone());
    }
    b.run("ingest/put-overwrite-50k/1bit-1024", n as u64, || {
        for (id, s) in ids.iter().zip(&sketches) {
            store.put(id.clone(), s.clone());
        }
    });

    // Fused bulk ingest: one contiguous word buffer per batch.
    let stride = store.arena().expect("arena-backed").stride();
    let batch = 4096usize;
    let mut words: Vec<u64> = Vec::with_capacity(batch * stride);
    for s in sketches.iter().take(batch) {
        words.extend_from_slice(s.words());
    }
    let batch_ids: Vec<String> = ids[..batch].to_vec();
    b.run("ingest/put-rows-4096/1bit-1024", batch as u64, || {
        store.put_rows(&batch_ids, &words).expect("bulk ingest");
    });

    // Drain the backlog, then measure ingest under continuous scan load:
    // a background thread sweeps top-10 queries nonstop while puts flow.
    store.arena().expect("arena-backed").drain();
    let store = Arc::new(store);
    let stop = Arc::new(AtomicBool::new(false));
    let query = random_sketch(&mut Pcg64::new(99, 9), k, bits);
    let scanner = {
        let store = store.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let arena = store.arena().expect("arena-backed");
            let mut sweeps = 0u64;
            while !stop.load(Ordering::Relaxed) {
                std::hint::black_box(arena.scan_topk(&query, 10, 1));
                sweeps += 1;
            }
            sweeps
        })
    };
    let mut next = 0usize;
    b.run("ingest/put-under-scan-load/1bit-1024", 1, || {
        let j = next % n;
        store.put(ids[j].clone(), sketches[j].clone());
        next += 1;
    });
    stop.store(true, Ordering::Relaxed);
    let sweeps = scanner.join().expect("scanner thread");
    eprintln!("background scanner completed {sweeps} sweeps during ingest");

    // Durability path: arena-image snapshot writes, per-record WAL
    // appends, and the cold bulk restore a restart pays.
    use crp::coordinator::durability::{snapshot, wal};
    let dir = std::env::temp_dir().join(format!("crp_ingest_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("bench temp dir");
    store.arena().expect("arena-backed").drain();
    let image = store.arena().expect("arena-backed").sealed_image();
    let snap_path = dir.join("snapshot.bin");
    b.run("durability/snapshot-write-50k/1bit-1024", n as u64, || {
        snapshot::save(&snap_path, &image).expect("snapshot write");
    });

    let w = wal::Wal::create(&dir, k, bits).expect("wal create");
    let mut j = 0usize;
    b.run("durability/wal-append-put/1bit-1024", 1, || {
        w.append_put(&ids[j % n], sketches[j % n].words(), || ())
            .expect("wal append");
        j += 1;
    });
    let batch_words = &words; // the 4096-row buffer from the bulk bench
    b.run("durability/wal-append-4096-rows/1bit-1024", batch as u64, || {
        w.append_put_rows(&batch_ids, batch_words, || ())
            .expect("wal bulk append");
    });

    b.run("durability/cold-restore-50k/1bit-1024", n as u64, || {
        let fresh = SketchStore::with_arena(k, bits);
        let img = snapshot::load(&snap_path).expect("snapshot load");
        snapshot::restore_into(&fresh, &img).expect("restore");
        assert_eq!(fresh.len(), n);
    });
    std::fs::remove_dir_all(&dir).ok();

    // Sparse projection front-end at the paper's scale: d = 2^20, k =
    // 256, CSR rows at 0.1% / 1% / 5% density. The dense baseline pays
    // O(d·k) per row regardless of content (timed externally over a few
    // rows — it is orders of magnitude slower); the gather kernel pays
    // O(nnz·k) for byte-identical codes, and the sign-sparse matrix
    // drops the multiplies on top of that.
    sparse_phase(&mut b);

    b.finish_json(std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_scan.json"
    )));
}

/// One CSR batch of `rows` random sorted rows with `nnz` nonzeros each
/// over `d` columns.
fn random_csr(g: &mut Pcg64, rows: usize, d: usize, nnz: usize) -> crp::data::CsrMatrix {
    let mut csr = crp::data::CsrMatrix::with_capacity(rows, rows * nnz, d);
    let mut idx: Vec<u32> = Vec::with_capacity(nnz);
    for _ in 0..rows {
        idx.clear();
        while idx.len() < nnz {
            idx.push(g.next_below(d as u64) as u32);
            if idx.len() == nnz {
                idx.sort_unstable();
                idx.dedup();
            }
        }
        let val: Vec<f32> = idx.iter().map(|_| g.next_f64() as f32 - 0.5).collect();
        csr.push_row(&idx, &val);
    }
    csr
}

fn sparse_phase(b: &mut harness::Bench) {
    use crp::coding::{BatchEncoder, CodingParams, Scheme};
    use crp::projection::{MatrixKind, ProjectionConfig, Projector};

    let (d, k) = (1usize << 20, 256usize);
    let params = CodingParams::new(Scheme::TwoBit, 0.75);
    let gaussian = Projector::new_cpu(ProjectionConfig {
        k,
        seed: 7,
        ..Default::default()
    });
    let signs = Projector::new_cpu(ProjectionConfig {
        k,
        seed: 7,
        kind: MatrixKind::SignSparse { s: 4 },
        ..Default::default()
    });
    let mut g = Pcg64::new(41, 0);
    let mut out: Vec<u64> = Vec::new();

    // Dense baseline: project + encode 2 densified 1%-density rows,
    // timed externally (one row costs d·k = 2^28 mults plus tile
    // generation — far too slow for the adaptive harness loop).
    let csr1 = random_csr(&mut g, 2, d, d / 100);
    let mut enc = BatchEncoder::new(params.clone(), k);
    let dense: Vec<f32> = (0..csr1.rows()).flat_map(|r| csr1.row_dense(r)).collect();
    let t0 = std::time::Instant::now();
    let x = gaussian.project_batch(&dense, csr1.rows(), d);
    enc.encode_pack_batch_into(&x, csr1.rows(), &mut out);
    let dense_ns = t0.elapsed().as_nanos() as f64 / csr1.rows() as f64;
    b.record(
        "sparse/encode-dense-baseline/d1M-nnz1pct",
        dense_ns,
        1e9 / dense_ns,
    );

    // Gather kernel at three densities: same codes, O(nnz·k) work.
    for (tag, frac) in [("0.1pct", 1000usize), ("1pct", 100), ("5pct", 20)] {
        let rows = 16usize;
        let csr = random_csr(&mut g, rows, d, d / frac);
        let mut enc = BatchEncoder::new(params.clone(), k);
        b.run(
            &format!("sparse/encode-csr-gather/d1M-nnz{tag}"),
            rows as u64,
            || enc.encode_csr(&gaussian, &csr, &mut out),
        );
    }

    // Sign-sparse matrix at 1%: add/sub only, no Gaussian row gen.
    let rows = 64usize;
    let csr = random_csr(&mut g, rows, d, d / 100);
    let mut enc = BatchEncoder::new(params, k);
    b.run("sparse/encode-csr-sign/d1M-nnz1pct", rows as u64, || {
        enc.encode_csr(&signs, &csr, &mut out)
    });
}
