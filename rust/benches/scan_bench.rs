//! Scan-engine throughput: the arena + kernel + top-k path against the
//! seed's HashMap-walk Knn loop, at 10⁵ sketches of 1024 one-bit codes
//! (the acceptance configuration) plus a 2-bit variant, batched fan-out,
//! and single-thread throughput of every collision-kernel tier the CPU
//! offers (SWAR vs SSE2 vs AVX2). Results merge into the repo-root
//! `BENCH_scan.json` for the PR-over-PR trajectory. Set
//! `SCAN_BENCH_LARGE=1` to add a 10⁶-sketch run.

#[path = "harness/mod.rs"]
mod harness;

use std::time::Instant;

use crp::coding::{collision_count_packed, CodingParams, PackedCodes, Scheme};
use crp::coordinator::SketchStore;
use crp::lsh::IndexConfig;
use crp::mathx::Pcg64;
use crp::scan::{
    scan_topk, scan_topk_batch, CodeArena, CollisionKernel, EpochArena, EpochConfig, KernelKind,
};

/// Random one-bit sketches are random words.
fn random_sketch(g: &mut Pcg64, k: usize, bits: u32) -> PackedCodes {
    let per_word = (64 / bits) as usize;
    let n_words = k.div_ceil(per_word);
    let mut words: Vec<u64> = (0..n_words).map(|_| g.next_u64()).collect();
    // Zero the padding bits of the last word (packing invariant).
    let rem = k % per_word;
    if rem > 0 {
        words[n_words - 1] &= (1u64 << (rem as u32 * bits)) - 1;
    }
    PackedCodes::from_words(bits, k, words)
}

struct Corpus {
    store: SketchStore,
    arena: CodeArena,
    query: PackedCodes,
}

fn build(n: usize, k: usize, bits: u32, seed: u64) -> Corpus {
    let mut g = Pcg64::new(seed, 0);
    let store = SketchStore::new(); // map-only: the seed's layout
    let mut arena = CodeArena::new(k, bits);
    for i in 0..n {
        let p = random_sketch(&mut g, k, bits);
        arena.insert(&format!("{i:07}"), &p);
        store.put(format!("{i:07}"), p);
    }
    let query = random_sketch(&mut g, k, bits);
    Corpus {
        store,
        arena,
        query,
    }
}

/// The seed coordinator's Knn loop, verbatim: walk every shard, allocate
/// an id per row, score pair-by-pair, full sort, truncate.
fn seed_knn(c: &Corpus, top: usize) -> Vec<(String, usize)> {
    let mut hits: Vec<(String, usize)> = Vec::new();
    c.store.for_each(|id, codes| {
        hits.push((id.to_string(), collision_count_packed(&c.query, codes)));
    });
    hits.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    hits.truncate(top);
    hits
}

/// Median seconds per call over `samples` timed calls.
fn median_secs<F: FnMut()>(samples: usize, mut f: F) -> f64 {
    let mut times: Vec<f64> = (0..samples)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Single-thread raw kernel throughput: sweep every arena row with one
/// tier, no top-k bookkeeping — the codes/s ceiling of that tier.
fn bench_kernel_tiers(b: &mut harness::Bench, c: &Corpus, bits: u32, label: &str) {
    let k = c.arena.k();
    let rows = c.arena.rows_allocated();
    let qwords = c.query.words();
    for kind in KernelKind::ALL {
        let Some(kernel) = CollisionKernel::with_kind(bits, kind) else {
            continue;
        };
        b.run(
            &format!("kernel/{label}-{}/single-thread", kind.label()),
            (rows * k) as u64,
            || {
                let mut acc = 0usize;
                for row in 0..rows as u32 {
                    acc += kernel.count(k, qwords, c.arena.row_words(row));
                }
                std::hint::black_box(acc);
            },
        );
    }
}

fn main() {
    let mut b = harness::Bench::new();
    let (n, k) = (100_000usize, 1024usize);
    let c1 = build(n, k, 1, 42);

    bench_kernel_tiers(&mut b, &c1, 1, "1bit-1024");

    b.run("scan/seed-hashmap-knn10/100k-1bit-1024", n as u64, || {
        std::hint::black_box(seed_knn(&c1, 10));
    });
    b.run("scan/arena-serial-top10/100k-1bit-1024", n as u64, || {
        std::hint::black_box(scan_topk(&c1.arena, &c1.query, 10, 1));
    });
    b.run("scan/arena-parallel-top10/100k-1bit-1024", n as u64, || {
        std::hint::black_box(scan_topk(&c1.arena, &c1.query, 10, 0));
    });

    // Batched fan-out: 16 queries answered in one call.
    let mut g = Pcg64::new(7, 7);
    let queries: Vec<PackedCodes> = (0..16).map(|_| random_sketch(&mut g, k, 1)).collect();
    b.run("scan/arena-batch16-top10/100k-1bit-1024", (16 * n) as u64, || {
        std::hint::black_box(scan_topk_batch(&c1.arena, &queries, 10, 0));
    });

    // ---- Observability overhead: the request-path instrumentation ---
    // The serving layer wraps every request in one Instant plus one
    // power-of-two histogram record (an atomic add). Run the exact-scan
    // path with and without that wrapper, under the series name the
    // instrumentation feeds, to pin the overhead (<2% target).
    let hist = crp::coordinator::metrics::LatencyHistogram::default();
    b.run("obs/crp_request_duration_us-off/100k-1bit-1024", n as u64, || {
        std::hint::black_box(scan_topk(&c1.arena, &c1.query, 10, 0));
    });
    b.run("obs/crp_request_duration_us-on/100k-1bit-1024", n as u64, || {
        let t = Instant::now();
        std::hint::black_box(scan_topk(&c1.arena, &c1.query, 10, 0));
        hist.record((t.elapsed().as_micros() as u64).max(1));
    });
    let off_s = median_secs(5, || {
        std::hint::black_box(scan_topk(&c1.arena, &c1.query, 10, 0));
    });
    let on_s = median_secs(5, || {
        let t = Instant::now();
        std::hint::black_box(scan_topk(&c1.arena, &c1.query, 10, 0));
        hist.record((t.elapsed().as_micros() as u64).max(1));
    });
    println!(
        "\nobservability overhead on the exact-scan path (timed + recorded vs bare): \
         {:+.2}%",
        100.0 * (on_s - off_s) / off_s
    );

    // The acceptance headline: arena scan vs the seed loop.
    let seed_s = median_secs(5, || {
        std::hint::black_box(seed_knn(&c1, 10));
    });
    let scan_s = median_secs(5, || {
        std::hint::black_box(scan_topk(&c1.arena, &c1.query, 10, 0));
    });
    println!(
        "\nscan speedup over seed HashMap Knn loop (100k x 1024 one-bit): {:.1}x",
        seed_s / scan_s
    );

    // 2-bit codes — the paper's recommended scheme for estimation.
    let c2 = build(50_000, k, 2, 43);
    bench_kernel_tiers(&mut b, &c2, 2, "2bit-1024");
    b.run("scan/seed-hashmap-knn10/50k-2bit-1024", 50_000, || {
        std::hint::black_box(seed_knn(&c2, 10));
    });
    b.run("scan/arena-parallel-top10/50k-2bit-1024", 50_000, || {
        std::hint::black_box(scan_topk(&c2.arena, &c2.query, 10, 0));
    });

    if std::env::var("SCAN_BENCH_LARGE").is_ok() {
        let c3 = build(1_000_000, k, 1, 44);
        b.run("scan/arena-parallel-top10/1m-1bit-1024", 1_000_000, || {
            std::hint::black_box(scan_topk(&c3.arena, &c3.query, 10, 0));
        });
    }

    // ---- ANN: the banded multi-probe index vs the exact oracle ------
    // The PR-5 acceptance configuration: 1e5 two-bit sketches of 256
    // codes (synthetic Gaussian projections, 12 planted rho=0.95
    // neighbors per query), approximate scans at the default probe
    // budget vs the exact sweep, with recall@10 measured against it.
    let (ann_n, ann_k, ann_q) = (100_000usize, 256usize, 32usize);
    let (ann, ann_queries) = build_ann(ann_n, ann_k, ann_q, 12, 0.95, 77);
    let q0 = &ann_queries[0];
    b.run("ann/exact-serial-top10/100k-2bit-256", ann_n as u64, || {
        std::hint::black_box(ann.scan_topk(q0, 10, 1));
    });
    b.run("ann/exact-parallel-top10/100k-2bit-256", ann_n as u64, || {
        std::hint::black_box(ann.scan_topk(q0, 10, 0));
    });
    b.run("ann/approx-top10-p2/100k-2bit-256", ann_n as u64, || {
        std::hint::black_box(ann.scan_topk_approx(q0, 10, 2));
    });

    // The acceptance headline: approx vs exact over the query set,
    // plus recall@10 against the exact oracle.
    let exact_s = median_secs(5, || {
        for q in &ann_queries {
            std::hint::black_box(ann.scan_topk(q, 10, 0));
        }
    });
    let approx_s = median_secs(5, || {
        for q in &ann_queries {
            std::hint::black_box(ann.scan_topk_approx(q, 10, 2));
        }
    });
    let mut found = 0usize;
    let mut wanted = 0usize;
    for q in &ann_queries {
        let exact = ann.scan_topk(q, 10, 0);
        let approx = ann.scan_topk_approx(q, 10, 2);
        wanted += exact.len();
        found += exact
            .iter()
            .filter(|e| approx.iter().any(|h| h.id == e.id))
            .count();
    }
    println!(
        "\nann approx speedup over exact parallel scan (100k x 256 two-bit): \
         {:.1}x at recall@10 {:.3}",
        exact_s / approx_s,
        found as f64 / wanted.max(1) as f64
    );

    b.finish_json(std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_scan.json"
    )));
}

/// Corpus for the ANN benches: Gaussian projections encoded with the
/// paper's 2-bit scheme at w = 0.75; each query is a base vector with
/// `planted` rho-correlated neighbors hidden in the corpus, so the
/// exact top-10 is dominated by true neighbors the index must find.
fn build_ann(
    n: usize,
    k: usize,
    queries: usize,
    planted: usize,
    rho: f64,
    seed: u64,
) -> (EpochArena, Vec<PackedCodes>) {
    let params = CodingParams::new(Scheme::TwoBit, 0.75);
    let bits = params.bits_per_code();
    let arena = EpochArena::with_index_config(
        k,
        bits,
        EpochConfig::default(),
        IndexConfig::for_shape(k, bits),
    );
    let (rows, qs) = crp::data::planted_code_corpus(&params, k, n, queries, planted, rho, seed);
    for (i, row) in rows.iter().enumerate() {
        let _ = arena.put(&format!("{i:07}"), row);
    }
    arena.drain();
    (arena, qs)
}
