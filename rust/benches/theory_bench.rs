//! Theory-layer benchmarks: collision probabilities, variance factors,
//! inversion tables — the analysis code behind Figures 1–10.

#[path = "harness/mod.rs"]
mod harness;

use crp::theory::{p_w, p_w2, p_wq, v_w, v_w2, v_wq, InversionTable, SchemeKind};

fn main() {
    let mut b = harness::Bench::new();

    b.run("collision/p_w(0.5, 0.75)", 1, || {
        std::hint::black_box(p_w(0.5, 0.75));
    });
    b.run("collision/p_wq(0.5, 0.75)", 1, || {
        std::hint::black_box(p_wq(0.5, 0.75));
    });
    b.run("collision/p_w2(0.5, 0.75)", 1, || {
        std::hint::black_box(p_w2(0.5, 0.75));
    });
    b.run("variance/v_w(0.5, 0.75)", 1, || {
        std::hint::black_box(v_w(0.5, 0.75));
    });
    b.run("variance/v_wq(0.5, 0.75)", 1, || {
        std::hint::black_box(v_wq(0.5, 0.75));
    });
    b.run("variance/v_w2(0.5, 0.75)", 1, || {
        std::hint::black_box(v_w2(0.5, 0.75));
    });
    b.run("optimum/argmin_w V_w(rho=0.9)", 1, || {
        std::hint::black_box(crp::theory::optimum_w(SchemeKind::Uniform, 0.9));
    });
    b.run("invert/table-build/2bit-2048pt", 2048, || {
        std::hint::black_box(InversionTable::build(SchemeKind::TwoBit, 0.75, 2048));
    });
    let table = InversionTable::build_default(SchemeKind::TwoBit, 0.75);
    b.run("invert/table-lookup", 1, || {
        std::hint::black_box(table.rho(0.6123));
    });

    b.finish();
}
