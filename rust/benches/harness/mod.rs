//! Minimal benchmark harness (criterion is not vendored in this
//! environment). Adaptive iteration count targeting a fixed measurement
//! window, warmup, and median-of-samples reporting. Honors the standard
//! `--bench` flag cargo passes and an optional substring filter.

use std::time::{Duration, Instant};

pub struct Bench {
    filter: Option<String>,
    results: Vec<(String, f64, f64)>, // name, median ns/iter, throughput
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        let mut filter = None;
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            if a == "--bench" || a.starts_with("--") {
                continue;
            }
            filter = Some(a);
        }
        Bench {
            filter,
            results: Vec::new(),
        }
    }

    /// Time `f`, which performs `items` logical units of work per call
    /// (used for the throughput column; pass 1 for latency-style runs).
    pub fn run<F: FnMut()>(&mut self, name: &str, items: u64, mut f: F) {
        if let Some(fil) = &self.filter {
            if !name.contains(fil.as_str()) {
                return;
            }
        }
        // Warmup + calibration: find iters/sample for ~30ms samples.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let iters = ((Duration::from_millis(30).as_nanos() / once.as_nanos()).max(1)) as u64;
        let samples = if once > Duration::from_millis(300) { 3 } else { 10 };
        let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = per_iter[per_iter.len() / 2];
        let throughput = items as f64 / (median / 1e9);
        println!(
            "{name:<52} {:>14} ns/iter {:>16} items/s",
            fmt_thousands(median as u64),
            fmt_thousands(throughput as u64)
        );
        self.results.push((name.to_string(), median, throughput));
    }

    /// Record an externally measured result (latency percentiles, whole
    /// phases timed by the caller) into the same report and JSON
    /// trajectory [`Bench::run`] feeds. Honors the substring filter.
    #[allow(dead_code)]
    pub fn record(&mut self, name: &str, ns_per_iter: f64, items_per_sec: f64) {
        if let Some(fil) = &self.filter {
            if !name.contains(fil.as_str()) {
                return;
            }
        }
        println!(
            "{name:<52} {:>14} ns/iter {:>16} items/s",
            fmt_thousands(ns_per_iter as u64),
            fmt_thousands(items_per_sec as u64)
        );
        self.results.push((name.to_string(), ns_per_iter, items_per_sec));
    }

    pub fn finish(&self) {
        println!("\n{} benchmarks run", self.results.len());
    }

    /// As [`Bench::finish`], then merge this run's results into a
    /// machine-readable JSON file (the PR-over-PR perf trajectory).
    /// Entries from previous runs whose names were not re-measured are
    /// kept, so `scan_bench` and `ingest_bench` share one file; each
    /// entry sits on its own line to keep the merge a line-level parse.
    #[allow(dead_code)]
    pub fn finish_json(&self, path: &std::path::Path) {
        self.finish();
        let mut entries: Vec<(String, String)> = Vec::new();
        if let Ok(prev) = std::fs::read_to_string(path) {
            for line in prev.lines() {
                let t = line.trim().trim_end_matches(',');
                if let Some(rest) = t.strip_prefix("{\"name\":\"") {
                    if let Some(name) = rest.split('"').next() {
                        entries.push((name.to_string(), t.to_string()));
                    }
                }
            }
        }
        for (name, median_ns, throughput) in &self.results {
            entries.retain(|(n, _)| n != name);
            entries.push((
                name.clone(),
                format!(
                    "{{\"name\":\"{name}\",\"ns_per_iter\":{median_ns:.1},\"items_per_sec\":{throughput:.1}}}"
                ),
            ));
        }
        entries.sort();
        let mut out = String::from("{\n\"schema\": \"crp-bench-v1\",\n\"benches\": [\n");
        for (i, (_, line)) in entries.iter().enumerate() {
            out.push_str(line);
            if i + 1 < entries.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]\n}\n");
        match std::fs::write(path, &out) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }
}

pub fn fmt_thousands(mut v: u64) -> String {
    let mut parts = Vec::new();
    loop {
        if v < 1000 {
            parts.push(v.to_string());
            break;
        }
        parts.push(format!("{:03}", v % 1000));
        v /= 1000;
    }
    parts.reverse();
    parts.join(",")
}
