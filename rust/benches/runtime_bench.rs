//! PJRT runtime benchmarks: artifact dispatch overhead and projection
//! throughput on the AOT path vs the pure-Rust path. Skips (with a
//! message) when `make artifacts` has not been run.

#[path = "harness/mod.rs"]
mod harness;

use crp::projection::{ProjectionConfig, Projector};
use crp::runtime::{ArtifactId, ArtifactRegistry, PjrtRuntime};
use std::sync::Arc;

fn main() {
    let mut b = harness::Bench::new();
    let reg = ArtifactRegistry::default_location();
    if !reg.exists(&ArtifactId::proj_acc(64, 1024, 256)) {
        println!("SKIP runtime_bench: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Arc::new(PjrtRuntime::cpu(reg).expect("PJRT runtime"));

    let cfg = ProjectionConfig {
        k: 256,
        seed: 1,
        d_tile: 1024,
        b_tile: 64,
        max_cached_tiles: 8,
        ..Default::default()
    };
    let pure = Projector::new_cpu(cfg.clone());
    let pjrt = Projector::new_pjrt(cfg, rt.clone());
    assert!(pjrt.pjrt_active());

    let (bsz, d) = (64usize, 1024usize);
    let mut g = crp::mathx::Pcg64::new(9, 0);
    let u: Vec<f32> = (0..bsz * d).map(|_| g.next_f64() as f32 - 0.5).collect();

    b.run("project/pure/b64-d1024-k256", (bsz * d * 256) as u64, || {
        std::hint::black_box(pure.project_batch(&u, bsz, d));
    });
    b.run("project/pjrt/b64-d1024-k256", (bsz * d * 256) as u64, || {
        std::hint::black_box(pjrt.project_batch(&u, bsz, d));
    });

    // Dispatch overhead: smallest artifact (collision count).
    let id = ArtifactId::collision(64, 256);
    let a: Vec<i32> = (0..64 * 256).map(|_| g.next_below(4) as i32).collect();
    let la = PjrtRuntime::literal_i32(&a, &[64, 256]).unwrap();
    let lb = PjrtRuntime::literal_i32(&a, &[64, 256]).unwrap();
    // Pre-compile.
    rt.executable(&id).unwrap();
    b.run("pjrt-dispatch/collision-b64-k256", (64 * 256) as u64, || {
        let la2 = la.clone();
        let lb2 = lb.clone();
        std::hint::black_box(rt.execute(&id, &[la2, lb2]).unwrap());
    });

    b.finish();
}
