//! Section-6 pipeline benchmarks: projection of sparse datasets, feature
//! expansion, and DCD training epochs (Figures 11–14's compute).

#[path = "harness/mod.rs"]
mod harness;

use crp::coding::{CodingParams, Scheme};
use crp::data::synth::{SynthKind, SynthSpec};
use crp::projection::{ProjectionConfig, Projector};
use crp::svm::dcd::{train_dcd, DcdConfig};
use crp::svm::sweep::{project_dataset, run_coded_svm, SvmTask};

fn main() {
    let mut b = harness::Bench::new();
    let spec = SynthSpec::small(SynthKind::FarmLike);
    let (train, test) = spec.generate();
    let k = 128;
    let projector = Projector::new_cpu(ProjectionConfig {
        k,
        seed: 3,
        ..Default::default()
    });

    b.run(
        &format!("project/sparse-dataset/{}rows-k{k}", train.len()),
        train.len() as u64,
        || {
            std::hint::black_box(project_dataset(&train, &projector));
        },
    );

    let ptr = project_dataset(&train, &projector);
    let pte = project_dataset(&test, &projector);

    for (name, task) in [
        ("orig", SvmTask::Orig),
        (
            "h_w2",
            SvmTask::Coded(CodingParams::new(Scheme::TwoBit, 0.75)),
        ),
    ] {
        b.run(
            &format!("svm-e2e/{name}/k{k}"),
            train.len() as u64,
            || {
                std::hint::black_box(run_coded_svm(
                    &ptr, &train.y, &pte, &test.y, k, &task, 1.0,
                ));
            },
        );
    }

    // Raw DCD on the expanded features (training only).
    let params = CodingParams::new(Scheme::TwoBit, 0.75);
    let card = params.cardinality();
    let mut x = crp::data::CsrMatrix::with_capacity(train.len(), train.len() * k, k * card);
    let mut codes = vec![0u16; k];
    for r in 0..train.len() {
        params.encode_into(&ptr[r * k..(r + 1) * k], None, &mut codes);
        let (idx, val) = crp::coding::expand_to_sparse(&codes, card);
        x.push_row(&idx, &val);
    }
    b.run(
        &format!("dcd-train/{}x{}nnz", train.len(), train.len() * k),
        (train.len() * k) as u64,
        || {
            std::hint::black_box(train_dcd(&x, &train.y, &DcdConfig::default()));
        },
    );

    b.finish();
}
