//! Per-figure regeneration benchmarks: one entry per paper figure, so
//! `cargo bench figures` measures the cost of reproducing the paper's
//! whole evaluation. SVM figures run at reduced scale (0.05) here; the
//! CLI (`crp figures --scale 1.0`) does the paper-scale runs.

#[path = "harness/mod.rs"]
mod harness;

use crp::figures::run_figure;

fn main() {
    let mut b = harness::Bench::new();
    // Theory figures: exact curves (Figures 1-10).
    for fig in [1u32, 2, 3, 4, 5, 6, 7, 8, 9, 10] {
        b.run(&format!("figure/{fig:02}"), 1, || {
            std::hint::black_box(run_figure(fig, 1.0).unwrap());
        });
    }
    // SVM figures at smoke scale (Figures 11-14).
    for fig in [11u32, 12, 13, 14] {
        b.run(&format!("figure/{fig:02}-scale0.05"), 1, || {
            std::hint::black_box(run_figure(fig, 0.05).unwrap());
        });
    }
    b.finish();
}
