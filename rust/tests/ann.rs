//! ANN correctness for the banded multi-probe index: recall@k pinned
//! against the exact scanner across coding schemes and bit widths,
//! score-exactness of approximate hits, probe monotonicity,
//! self-retrieval, and pending-rows-visible-before-drain freshness.
//!
//! Run standalone with `cargo test --release -q ann` (CI does).
//!
//! Corpus model: the paper's — projected coordinates are iid N(0,1) —
//! so rows are sampled directly in projection space and encoded with
//! each scheme; each query's planted neighbors are ρ-correlated views
//! of its base vector. Seeds are fixed, so these are deterministic
//! pins with wide margins (expected recall ≈ 0.99 at the pinned 0.9).

use crp::coding::{pack_codes, CodingParams, PackedCodes, Scheme};
use crp::data::planted_code_corpus;
use crp::lsh::{IndexConfig, APPROX_MIN_ROWS};
use crp::mathx::NormalSampler;
use crp::scan::{EpochArena, EpochConfig};

const K: usize = 192;
const QUERIES: usize = 8;
const PLANTED: usize = 14;
const RHO: f64 = 0.95;

struct AnnCase {
    arena: EpochArena,
    queries: Vec<PackedCodes>,
}

/// `n` rows total: for each query, `PLANTED` neighbors at similarity
/// `RHO` to the query's base (the query is the base itself, so exact
/// top-10 is dominated by planted rows); the rest independent.
fn build(scheme: Scheme, w: f64, n: usize, seed: u64) -> AnnCase {
    let params = CodingParams::new(scheme, w);
    let bits = params.bits_per_code();
    let arena = EpochArena::with_index_config(
        K,
        bits,
        EpochConfig::default(),
        IndexConfig::for_shape(K, bits),
    );
    let (rows, queries) = planted_code_corpus(&params, K, n, QUERIES, PLANTED, RHO, seed);
    for (i, row) in rows.iter().enumerate() {
        let _ = arena.put(&format!("r{i:06}"), row);
    }
    arena.drain();
    AnnCase { arena, queries }
}

fn recall_at(case: &AnnCase, top: usize, probes: usize) -> f64 {
    let mut found = 0usize;
    let mut wanted = 0usize;
    for q in &case.queries {
        let exact = case.arena.scan_topk(q, top, 0);
        let approx = case.arena.scan_topk_approx(q, top, probes);
        wanted += exact.len();
        for hit in &exact {
            if approx.iter().any(|h| h.id == hit.id) {
                found += 1;
            }
        }
    }
    found as f64 / wanted.max(1) as f64
}

/// The acceptance pin: recall@10 ≥ 0.9 against the exact oracle for
/// every scheme/width the serving stack offers, and every approximate
/// hit carries exactly the collision count the exact scan reports.
#[test]
fn ann_recall_pinned_vs_exact_across_schemes() {
    // 1-bit, 2-bit (the paper's pick), and 4-bit codes.
    for (scheme, w) in [
        (Scheme::OneBit, 0.0),
        (Scheme::TwoBit, 0.75),
        (Scheme::Uniform, 1.0),
    ] {
        let case = build(scheme, w, APPROX_MIN_ROWS + 3000, 0x1234 + w.to_bits() as u64);
        assert!(case.arena.index_buckets() > 0, "{scheme:?}");
        let recall = recall_at(&case, 10, 2);
        assert!(
            recall >= 0.9,
            "{scheme:?} w={w}: recall@10 {recall} < 0.9"
        );
        // Score exactness: an approx hit's collision count equals the
        // full sweep's count for that id (candidates are reranked
        // through the same kernels — no estimated scores anywhere).
        let q = &case.queries[0];
        let exact_all = case.arena.scan_topk(q, APPROX_MIN_ROWS + 3000, 0);
        for hit in case.arena.scan_topk_approx(q, 10, 2) {
            let full = exact_all
                .iter()
                .find(|e| e.id == hit.id)
                .unwrap_or_else(|| panic!("{scheme:?}: {} missing from exact", hit.id));
            assert_eq!(hit.collisions, full.collisions, "{scheme:?} {}", hit.id);
        }
    }
}

/// More probes only ever help, and an exact duplicate of a stored row
/// is always retrieved first (every band matches — self-retrieval is
/// structural, not probabilistic).
#[test]
fn ann_probes_monotone_and_self_retrieval() {
    let case = build(Scheme::TwoBit, 0.75, APPROX_MIN_ROWS + 2000, 0xBEEF);
    let r0 = recall_at(&case, 10, 0);
    let r4 = recall_at(&case, 10, 4);
    assert!(
        r4 >= r0 - 1e-12,
        "probes must not lose recall: {r0} -> {r4}"
    );
    for row in [0usize, 777, 1500] {
        let id = format!("r{row:06}");
        let q = case.arena.get(&id).unwrap();
        let hits = case.arena.scan_topk_approx(&q, 1, 0);
        assert_eq!(hits[0].id, id);
        assert_eq!(hits[0].collisions, K);
    }
}

/// Freshness: rows still in the pending epoch (never drained, never
/// indexed) are swept exactly, so an approximate query sees a write
/// the moment it is acknowledged; removes hide sealed rows just as
/// immediately.
#[test]
fn ann_pending_rows_visible_before_drain() {
    let case = build(Scheme::TwoBit, 0.75, APPROX_MIN_ROWS + 1500, 0x50DA);
    let arena = &case.arena;
    let params = CodingParams::new(Scheme::TwoBit, 0.75);
    let mut ns = NormalSampler::new(99, 1);
    let mut v = vec![0f32; K];
    ns.fill_f32(&mut v);
    let codes = pack_codes(&params.encode(&v), 2);
    let _ = arena.put("fresh", &codes);
    let hits = arena.scan_topk_approx(&codes, 1, 0);
    assert_eq!(hits[0].id, "fresh", "pending row must be visible pre-drain");
    assert_eq!(hits[0].collisions, K);
    // A pending overwrite shadows the sealed row's old content.
    let q_old = arena.get("r000042").unwrap();
    let _ = arena.put("r000042", &codes);
    let hits = arena.scan_topk_approx(&q_old, 2, 2);
    assert!(hits.iter().all(|h| h.collisions < K || h.id != "r000042"));
    // A remove hides a sealed row with no drain in between.
    assert!(arena.remove("r000100"));
    let gone = arena.get("r000100");
    assert!(gone.is_none());
    let hits = arena.scan_topk_approx(&codes, 5, 2);
    assert!(hits.iter().all(|h| h.id != "r000100"));
}

/// Below the exact-fallback floor the approximate path IS the exact
/// path, byte for byte.
#[test]
fn ann_small_stores_fall_back_to_exact() {
    let params = CodingParams::new(Scheme::OneBit, 0.0);
    let arena = EpochArena::with_index_config(
        64,
        1,
        EpochConfig::default(),
        IndexConfig::for_shape(64, 1),
    );
    let mut ns = NormalSampler::new(7, 7);
    let mut v = vec![0f32; 64];
    for i in 0..200 {
        ns.fill_f32(&mut v);
        let _ = arena.put(&format!("s{i:03}"), &pack_codes(&params.encode(&v), 1));
    }
    arena.drain();
    ns.fill_f32(&mut v);
    let q = pack_codes(&params.encode(&v), 1);
    assert_eq!(arena.scan_topk_approx(&q, 10, 3), arena.scan_topk(&q, 10, 1));
}
