//! Serving front-end tests: the epoll reactor answering byte-identically
//! to the blocking thread-per-connection oracle (sequential and
//! pipelined, including the coalesced bulk paths), framing edge cases
//! (slowloris, torn and oversized frames), the reactor observability
//! counters reaching `StatsDetailed`, and the PR-10 sharded front-end:
//! multi-loop oracle equivalence, worker-pool offload ordering, idle
//! disconnects, and cooperative shutdown.
//!
//! Run standalone with `cargo test --release -q serve` (CI does, twice:
//! once as-is and once under `CRP_SERVE_MODE=reactor-multi`, which
//! re-runs every reactor-mode server here as 4 SO_REUSEPORT loops + 2
//! workers).
#![cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]

use std::io::{BufReader, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crp::coding::Scheme;
use crp::coordinator::protocol::{self, Request, Response};
use crp::coordinator::server::{serve, ServerConfig, ServerMode};
use crp::coordinator::SketchClient;
use crp::data::CsrMatrix;
use crp::mathx::Pcg64;
use crp::projection::{MatrixKind, ProjectionConfig, Projector};

/// Spawn a server with `mode` plus config tweaks, returning its bound
/// address and the serve-thread handle (joinable after a cooperative
/// shutdown; every other test just drops it).
///
/// `CRP_SERVE_MODE=reactor-multi` (the CI matrix leg) upgrades every
/// reactor-mode server to 4 SO_REUSEPORT loops + 2 workers, so the
/// whole suite — oracle comparisons included — re-runs against the
/// sharded front-end. Thread-mode servers are the oracle and are never
/// reconfigured.
fn spawn_server_cfg(
    mode: ServerMode,
    tweak: impl FnOnce(&mut ServerConfig),
) -> (String, std::thread::JoinHandle<crp::Result<()>>) {
    let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
        k: 64,
        seed: 7,
        ..Default::default()
    }));
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        server_mode: mode,
        ..Default::default()
    };
    if mode == ServerMode::Reactor
        && std::env::var("CRP_SERVE_MODE").as_deref() == Ok("reactor-multi")
    {
        cfg.reactor_threads = 4;
        cfg.reactor_workers = 2;
    }
    tweak(&mut cfg);
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || serve(projector, cfg, Some(tx)));
    let addr = rx
        .recv()
        .expect("server thread exited before reporting its bound address")
        .to_string();
    (addr, handle)
}

fn spawn_server(mode: ServerMode) -> String {
    spawn_server_cfg(mode, |_| {}).0
}

fn vec_of(g: &mut Pcg64, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| g.next_f64() as f32 - 0.5).collect()
}

/// `rows` random CSR rows over `cols` columns, roughly 1/3 dense (some
/// rows come out empty — the protocol must carry those too).
fn csr_of(g: &mut Pcg64, rows: usize, cols: usize) -> CsrMatrix {
    let mut csr = CsrMatrix::with_capacity(rows, 0, cols);
    let (mut idx, mut val) = (Vec::new(), Vec::new());
    for _ in 0..rows {
        idx.clear();
        val.clear();
        for c in 0..cols as u32 {
            if g.next_below(3) == 0 {
                idx.push(c);
                val.push(g.next_f64() as f32 - 0.5);
            }
        }
        csr.push_row(&idx, &val);
    }
    csr
}

/// Send `script` over one raw connection and return the raw response
/// frame payloads, in order. Pipelined mode writes every request before
/// reading anything, so the reactor sees the whole burst at once and
/// exercises its fused dispatch paths.
fn run_script(addr: &str, script: &[Request], pipelined: bool) -> Vec<Vec<u8>> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut responses = Vec::with_capacity(script.len());
    if pipelined {
        let mut burst = Vec::new();
        for req in script {
            let payload = req.encode();
            burst.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            burst.extend_from_slice(&payload);
        }
        stream.write_all(&burst).unwrap();
    }
    for req in script {
        if !pipelined {
            protocol::write_frame(&mut stream, &req.encode()).unwrap();
        }
        let mut frame = Vec::new();
        protocol::read_frame_into(&mut reader, &mut frame)
            .unwrap_or_else(|e| panic!("no response to {req:?}: {e}"));
        responses.push(frame);
    }
    responses
}

/// The value of an unlabeled series on the exposition page.
fn metric_value(text: &str, series: &str) -> Option<u64> {
    text.lines().find_map(|l| {
        l.strip_prefix(series)
            .and_then(|rest| rest.strip_prefix(' '))
            .and_then(|v| v.trim().parse::<f64>().ok())
            .map(|v| v as u64)
    })
}

/// Requests whose answers carry timing- or mode-dependent fields
/// (latency percentiles, batch-size aggregates, reactor counters) are
/// compared structurally; everything else must match byte for byte.
fn timing_dependent(req: &Request) -> bool {
    matches!(
        req,
        Request::Stats | Request::StatsDetailed | Request::MetricsText | Request::ReplSync { .. }
    )
}

fn compare_structural(req: &Request, threads: &[u8], reactor: &[u8]) {
    let a = Response::decode(threads).unwrap();
    let b = Response::decode(reactor).unwrap();
    match (a, b) {
        (Response::Stats(x), Response::Stats(y)) => {
            assert_eq!(x.registered, y.registered, "{req:?}");
            assert_eq!(x.knn_queries, y.knn_queries, "{req:?}");
            assert_eq!(x.collections, y.collections, "{req:?}");
            assert_eq!(x.per_collection.len(), y.per_collection.len(), "{req:?}");
            for (cx, cy) in x.per_collection.iter().zip(&y.per_collection) {
                assert_eq!(cx.name, cy.name);
                assert_eq!(cx.rows, cy.rows, "{} rows diverged", cx.name);
            }
        }
        (Response::MetricsText { text: tx }, Response::MetricsText { text: ty }) => {
            for series in ["crp_registered_total", "crp_knn_queries_total", "crp_collections"] {
                assert_eq!(
                    metric_value(&tx, series),
                    metric_value(&ty, series),
                    "{series} diverged across serve modes"
                );
            }
            // Both pages carry the reactor series; only the reactor's
            // are live.
            for t in [&tx, &ty] {
                assert!(t.contains("# TYPE crp_reactor_ready_events counter"));
                assert!(t.contains("# TYPE crp_batcher_queue_depth gauge"));
            }
            assert_eq!(metric_value(&tx, "crp_reactor_frames"), Some(0));
            assert!(metric_value(&ty, "crp_reactor_frames").unwrap() > 0);
        }
        (Response::Error { message: ma }, Response::Error { message: mb }) => {
            assert_eq!(ma, mb, "{req:?}");
        }
        (
            Response::ReplBootstrap { snapshot: sa, .. },
            Response::ReplBootstrap { snapshot: sb, .. },
        ) => {
            assert_eq!(sa, sb, "{req:?}: bootstrap images diverged");
        }
        (x, y) => {
            assert_eq!(
                std::mem::discriminant(&x),
                std::mem::discriminant(&y),
                "{req:?}: {x:?} vs {y:?}"
            );
        }
    }
}

/// A deterministic script covering every request kind: data path
/// (scoped and legacy), admin, errors, replication probes, and the
/// introspection frames.
fn full_script() -> Vec<Request> {
    let mut g = Pcg64::new(42, 11);
    let mut sc = vec![Request::Ping];
    for i in 0..12 {
        sc.push(Request::Register {
            id: format!("a{i}"),
            vector: vec_of(&mut g, 24),
        });
    }
    sc.push(Request::RegisterBatch {
        ids: (0..8).map(|i| format!("b{i}")).collect(),
        vectors: (0..8).map(|_| vec_of(&mut g, 24)).collect(),
    });
    sc.push(Request::Estimate {
        a: "a0".into(),
        b: "a1".into(),
    });
    sc.push(Request::EstimateVec {
        id: "a2".into(),
        vector: vec_of(&mut g, 24),
    });
    sc.push(Request::Knn {
        vector: vec_of(&mut g, 24),
        n: 5,
    });
    sc.push(Request::TopK {
        vectors: vec![vec_of(&mut g, 24), vec_of(&mut g, 24)],
        n: 3,
    });
    sc.push(Request::ApproxTopK {
        vectors: vec![vec_of(&mut g, 24)],
        n: 3,
        probes: 2,
    });
    sc.push(Request::Remove { id: "a3".into() });
    sc.push(Request::Remove { id: "a3".into() }); // existed = false
    sc.push(Request::CreateCollection {
        name: "web".into(),
        scheme: Scheme::OneBit,
        w: 0.0,
        bits: 0,
        k: 64,
        seed: 5,
        checkpoint_every: 0,
        kind: MatrixKind::Gaussian,
    });
    for i in 0..6 {
        sc.push(Request::Scoped {
            collection: "web".into(),
            inner: Box::new(Request::Register {
                id: format!("w{i}"),
                vector: vec_of(&mut g, 16),
            }),
        });
    }
    // Sparse ingest: bare (default collection), scoped, the
    // unknown-collection error, and an ids/rows shape mismatch — every
    // response must come back byte-identical across serve modes.
    sc.push(Request::RegisterSparse {
        ids: (0..5).map(|i| format!("sp{i}")).collect(),
        csr: csr_of(&mut g, 5, 24),
    });
    sc.push(Request::Scoped {
        collection: "web".into(),
        inner: Box::new(Request::RegisterSparse {
            ids: (0..3).map(|i| format!("wsp{i}")).collect(),
            csr: csr_of(&mut g, 3, 16),
        }),
    });
    sc.push(Request::Scoped {
        collection: "nope".into(),
        inner: Box::new(Request::RegisterSparse {
            ids: vec!["x".into()],
            csr: csr_of(&mut g, 1, 16),
        }),
    });
    sc.push(Request::RegisterSparse {
        ids: vec!["short".into()],
        csr: csr_of(&mut g, 2, 24),
    });
    sc.push(Request::Scoped {
        collection: "web".into(),
        inner: Box::new(Request::TopK {
            vectors: vec![vec_of(&mut g, 16)],
            n: 2,
        }),
    });
    // Unknown-collection errors must come back byte-identical too (the
    // reactor rebuilds these requests out of its fusion scan).
    sc.push(Request::Scoped {
        collection: "nope".into(),
        inner: Box::new(Request::Register {
            id: "x".into(),
            vector: vec_of(&mut g, 16),
        }),
    });
    sc.push(Request::Scoped {
        collection: "nope".into(),
        inner: Box::new(Request::TopK {
            vectors: vec![vec_of(&mut g, 16)],
            n: 2,
        }),
    });
    // A sign-sparse collection created over the wire: the optional
    // matrix-kind tail must decode the same in both modes, and sparse
    // rows land in it like any other.
    sc.push(Request::CreateCollection {
        name: "signs".into(),
        scheme: Scheme::TwoBit,
        w: 0.75,
        bits: 0,
        k: 64,
        seed: 8,
        checkpoint_every: 0,
        kind: MatrixKind::SignSparse { s: 4 },
    });
    sc.push(Request::Scoped {
        collection: "signs".into(),
        inner: Box::new(Request::RegisterSparse {
            ids: (0..4).map(|i| format!("sg{i}")).collect(),
            csr: csr_of(&mut g, 4, 32),
        }),
    });
    sc.push(Request::Scoped {
        collection: "signs".into(),
        inner: Box::new(Request::TopK {
            vectors: vec![vec_of(&mut g, 32)],
            n: 2,
        }),
    });
    sc.push(Request::ListCollections);
    sc.push(Request::SlowQueries { max: 0 });
    sc.push(Request::Persist); // no durability → deterministic error
    sc.push(Request::Promote); // primary → was_replica = false
    sc.push(Request::ReplSync {
        collection: "default".into(),
        replica: "probe".into(),
        segment: 0,
        offset: 0,
    });
    sc.push(Request::Stats);
    sc.push(Request::StatsDetailed);
    sc.push(Request::MetricsText);
    sc.push(Request::Ping);
    sc
}

/// The dual-mode oracle pin: one deterministic script covering every
/// request kind, answered by a thread-mode and a reactor-mode server.
/// Deterministic answers must match byte for byte; timing-dependent
/// frames (stats, metrics, replication probes) must agree structurally.
#[test]
fn serve_reactor_answers_byte_identical_to_thread_oracle() {
    let script = full_script();
    let threads = run_script(&spawn_server(ServerMode::Threads), &script, false);
    let reactor = run_script(&spawn_server(ServerMode::Reactor), &script, false);
    assert_eq!(threads.len(), reactor.len());
    for ((req, a), b) in script.iter().zip(&threads).zip(&reactor) {
        if timing_dependent(req) {
            compare_structural(req, a, b);
        } else {
            assert_eq!(a, b, "response to {req:?} diverged across serve modes");
        }
    }
}

/// A fusion-heavy deterministic script: consecutive Registers (the
/// coalesced bulk-register path), a Register→Remove→Register triplet on
/// one id (program order must survive fusion), consecutive TopKs (the
/// fused batch scan), scoped runs, and an unknown-collection error in
/// the middle of a fusable run.
fn fusion_script() -> Vec<Request> {
    let mut g = Pcg64::new(7, 3);
    let mut sc = vec![Request::Ping];
    for i in 0..16 {
        sc.push(Request::Register {
            id: format!("f{i}"),
            vector: vec_of(&mut g, 24),
        });
    }
    sc.push(Request::Remove { id: "f0".into() });
    sc.push(Request::Register {
        id: "f0".into(),
        vector: vec_of(&mut g, 24),
    });
    for _ in 0..4 {
        sc.push(Request::TopK {
            vectors: vec![vec_of(&mut g, 24)],
            n: 3,
        });
    }
    // A run of consecutive RegisterSparse frames: the reactor merges
    // the CSR batches into one bulk ingest but still owes each frame
    // its own row count. One id ("sp0") repeats across two frames with
    // different rows — program order must survive the merge (the later
    // frame's row wins, exactly as in thread mode).
    for f in 0..5 {
        sc.push(Request::RegisterSparse {
            ids: (0..3).map(|i| format!("sp{}", f * 3 + i)).collect(),
            csr: csr_of(&mut g, 3, 24),
        });
    }
    sc.push(Request::RegisterSparse {
        ids: vec!["sp0".into()],
        csr: csr_of(&mut g, 1, 24),
    });
    // A shape-mismatched frame inside the fusable run: it must break
    // out of the merge and answer its own error without poisoning the
    // frames around it.
    sc.push(Request::RegisterSparse {
        ids: vec!["bad".into()],
        csr: csr_of(&mut g, 2, 24),
    });
    sc.push(Request::RegisterSparse {
        ids: (0..3).map(|i| format!("sq{i}")).collect(),
        csr: csr_of(&mut g, 3, 24),
    });
    sc.push(Request::CreateCollection {
        name: "web".into(),
        scheme: Scheme::TwoBit,
        w: 0.75,
        bits: 0,
        k: 64,
        seed: 9,
        checkpoint_every: 0,
        kind: MatrixKind::Gaussian,
    });
    for i in 0..6 {
        sc.push(Request::Scoped {
            collection: "web".into(),
            inner: Box::new(Request::Register {
                id: format!("w{i}"),
                vector: vec_of(&mut g, 16),
            }),
        });
    }
    // Scoped RegisterSparse runs fuse per collection like scoped
    // Registers do.
    for f in 0..3 {
        sc.push(Request::Scoped {
            collection: "web".into(),
            inner: Box::new(Request::RegisterSparse {
                ids: (0..2).map(|i| format!("wsp{}", f * 2 + i)).collect(),
                csr: csr_of(&mut g, 2, 16),
            }),
        });
    }
    sc.push(Request::Scoped {
        collection: "nope".into(),
        inner: Box::new(Request::Register {
            id: "x".into(),
            vector: vec_of(&mut g, 16),
        }),
    });
    for _ in 0..2 {
        sc.push(Request::Scoped {
            collection: "web".into(),
            inner: Box::new(Request::TopK {
                vectors: vec![vec_of(&mut g, 16)],
                n: 2,
            }),
        });
    }
    sc.push(Request::Knn {
        vector: vec_of(&mut g, 24),
        n: 4,
    });
    sc.push(Request::Estimate {
        a: "f1".into(),
        b: "f2".into(),
    });
    sc.push(Request::Ping);
    sc
}

/// Pipelined ≡ sequential, and reactor ≡ thread oracle under pipelining:
/// the whole burst lands in one readiness event, the reactor fuses what
/// it can, and every response byte still matches a server that handled
/// the same frames strictly one at a time.
#[test]
fn serve_pipelined_responses_match_sequential_byte_for_byte() {
    let script = fusion_script();
    let seq_reactor = run_script(&spawn_server(ServerMode::Reactor), &script, false);
    let pip_threads = run_script(&spawn_server(ServerMode::Threads), &script, true);

    // The reactor only fuses frames that arrive within one readiness
    // event; retry the burst on fresh servers until the stats show at
    // least one coalesced batch, so the fused paths are genuinely the
    // ones being byte-compared.
    let mut fused = 0u64;
    for attempt in 0..20 {
        let addr = spawn_server(ServerMode::Reactor);
        let pip_reactor = run_script(&addr, &script, true);
        assert_eq!(pip_reactor, seq_reactor, "pipelined != sequential (attempt {attempt})");
        assert_eq!(pip_reactor, pip_threads, "reactor != thread oracle (attempt {attempt})");
        let st = SketchClient::connect(&addr).unwrap().stats_detailed().unwrap();
        let r = st.reactor.expect("StatsDetailed must carry the reactor section");
        assert!(r.frames >= script.len() as u64, "parsed {} < {} frames", r.frames, script.len());
        assert!(r.polls > 0 && r.ready_events > 0);
        fused = r.coalesced_batches;
        if fused > 0 {
            assert!(r.p99_dispatch >= 1, "non-idle ticks must record dispatch sizes");
            assert!(r.write_buffer_hwm > 0, "responses must have queued in the write buffer");
            break;
        }
    }
    assert!(fused > 0, "20 pipelined bursts never landed in one tick");
}

/// Slowloris isolation: a peer dribbling one byte every 10 ms must not
/// stall anyone else. A fast client completes dozens of round trips in
/// far less time than the dribble takes, and the slow connection still
/// gets its correct answer at the end.
#[test]
fn serve_slowloris_never_stalls_other_connections() {
    let addr = spawn_server(ServerMode::Reactor);
    let payload = Request::Register {
        id: "slow".into(),
        vector: vec![0.25; 8],
    }
    .encode();
    let mut framed = (payload.len() as u32).to_le_bytes().to_vec();
    framed.extend_from_slice(&payload);

    let slow_addr = addr.clone();
    let slow = std::thread::spawn(move || {
        let mut s = TcpStream::connect(&slow_addr).unwrap();
        s.set_nodelay(true).unwrap();
        let start = Instant::now();
        for b in &framed {
            s.write_all(std::slice::from_ref(b)).unwrap();
            std::thread::sleep(Duration::from_millis(10));
        }
        let mut frame = Vec::new();
        protocol::read_frame_into(&mut s, &mut frame).unwrap();
        (frame, start.elapsed())
    });

    // Give the dribble a head start so the fast client genuinely
    // overlaps it.
    std::thread::sleep(Duration::from_millis(30));
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let ping = Request::Ping.encode();
    let start = Instant::now();
    let mut frame = Vec::new();
    for _ in 0..30 {
        protocol::write_frame(&mut stream, &ping).unwrap();
        protocol::read_frame_into(&mut reader, &mut frame).unwrap();
        assert_eq!(Response::decode(&frame).unwrap(), Response::Pong);
    }
    let fast_elapsed = start.elapsed();

    let (slow_frame, slow_elapsed) = slow.join().unwrap();
    assert_eq!(
        Response::decode(&slow_frame).unwrap(),
        Response::Registered { id: "slow".into() }
    );
    assert!(
        fast_elapsed < slow_elapsed / 2,
        "30 fast round trips took {fast_elapsed:?} against a {slow_elapsed:?} slowloris"
    );
}

/// Torn and oversized frames close the one bad connection cleanly —
/// no response bytes, no stuck state — and the server keeps answering
/// everyone else.
#[test]
fn serve_torn_and_oversized_frames_close_cleanly() {
    let addr = spawn_server(ServerMode::Reactor);

    // Half a length header, then EOF.
    let mut torn_header = TcpStream::connect(&addr).unwrap();
    torn_header.write_all(&[7, 0]).unwrap();
    torn_header.shutdown(Shutdown::Write).unwrap();

    // A full header promising 100 bytes, 10 delivered, then EOF.
    let mut torn_payload = TcpStream::connect(&addr).unwrap();
    torn_payload.write_all(&100u32.to_le_bytes()).unwrap();
    torn_payload.write_all(&[0u8; 10]).unwrap();
    torn_payload.shutdown(Shutdown::Write).unwrap();

    // A header past MAX_FRAME: the server hangs up without reading on.
    let mut oversized = TcpStream::connect(&addr).unwrap();
    oversized.write_all(&(protocol::MAX_FRAME + 1).to_le_bytes()).unwrap();

    let mut buf = [0u8; 16];
    for (label, s) in [
        ("torn header", &mut torn_header),
        ("torn payload", &mut torn_payload),
        ("oversized", &mut oversized),
    ] {
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        assert_eq!(s.read(&mut buf).unwrap(), 0, "{label}: expected a clean close");
    }

    // The server is still healthy for new connections.
    let mut c = SketchClient::connect(&addr).unwrap();
    c.ping().unwrap();
    let st = c.stats_detailed().unwrap();
    assert_eq!(st.connections, 1, "closed connections must release their slots");
}

/// The sharded front-end is held to the same oracle as the single
/// loop: 4 SO_REUSEPORT loops answer the full request-kind script byte
/// for byte (one connection lands on one loop, so per-connection
/// semantics are untouched by sharding), and `StatsDetailed` carries
/// the per-loop breakdown with the aggregates equal to the shard sums.
#[test]
fn serve_multi_reactor_answers_byte_identical_to_thread_oracle() {
    let script = full_script();
    let threads = run_script(&spawn_server(ServerMode::Threads), &script, false);
    let (addr, _h) = spawn_server_cfg(ServerMode::Reactor, |c| {
        c.reactor_threads = 4;
        c.reactor_workers = 0;
    });
    let multi = run_script(&addr, &script, false);
    assert_eq!(threads.len(), multi.len());
    for ((req, a), b) in script.iter().zip(&threads).zip(&multi) {
        if timing_dependent(req) {
            compare_structural(req, a, b);
        } else {
            assert_eq!(a, b, "response to {req:?} diverged under --reactor-threads 4");
        }
    }
    let st = SketchClient::connect(&addr).unwrap().stats_detailed().unwrap();
    let r = st.reactor.expect("reactor section present");
    assert_eq!(r.per_loop.len(), 4, "one shard per loop");
    assert_eq!(
        r.per_loop.iter().map(|l| l.frames).sum::<u64>(),
        r.frames,
        "aggregate frames must equal the shard sum"
    );
    assert!(
        r.per_loop.iter().map(|l| l.connections).sum::<u64>() >= 1,
        "the stats connection itself is owned by some loop"
    );
}

/// Worker-pool offload: a pipelined fusion-heavy burst against
/// `--reactor-workers 2` must still answer byte-identically to the
/// thread oracle — per-connection program order and per-frame ack
/// order survive the off-loop execution — and the offload counters
/// must show the pool actually ran fused batches. Fusion needs the
/// burst to land in one readiness event, so the offload attempt is
/// retried on fresh servers like the inline fusion test above.
#[test]
fn serve_workers_offload_fused_runs_byte_identical() {
    let script = fusion_script();
    let oracle = run_script(&spawn_server(ServerMode::Threads), &script, false);
    let mut offloaded = 0u64;
    for attempt in 0..20 {
        let (addr, _h) = spawn_server_cfg(ServerMode::Reactor, |c| {
            c.reactor_workers = 2;
        });
        let got = run_script(&addr, &script, true);
        assert_eq!(got.len(), oracle.len());
        for ((req, a), b) in script.iter().zip(&oracle).zip(&got) {
            if timing_dependent(req) {
                compare_structural(req, a, b);
            } else {
                assert_eq!(
                    a, b,
                    "response to {req:?} diverged under worker offload (attempt {attempt})"
                );
            }
        }
        let st = SketchClient::connect(&addr).unwrap().stats_detailed().unwrap();
        let r = st.reactor.expect("reactor section present");
        offloaded = r.offloaded_batches;
        if offloaded > 0 {
            assert!(
                r.coalesced_batches >= offloaded,
                "every offloaded batch was coalesced first"
            );
            assert_eq!(r.worker_queue_depth, 0, "queue drains once the burst is answered");
            break;
        }
    }
    assert!(offloaded > 0, "20 pipelined bursts never offloaded a fused run");
}

/// Cross-loop isolation: with 4 loops, a slowloris dribbling its frame
/// must not stall a fast client — whichever loops the kernel hashes
/// the two connections onto (same or different), the fast client's
/// round trips complete while the dribble is still in progress.
#[test]
fn serve_multi_loop_slowloris_never_stalls_fast_client() {
    let (addr, _h) = spawn_server_cfg(ServerMode::Reactor, |c| {
        c.reactor_threads = 4;
    });
    let payload = Request::Register {
        id: "slow".into(),
        vector: vec![0.25; 8],
    }
    .encode();
    let mut framed = (payload.len() as u32).to_le_bytes().to_vec();
    framed.extend_from_slice(&payload);

    let slow_addr = addr.clone();
    let slow = std::thread::spawn(move || {
        let mut s = TcpStream::connect(&slow_addr).unwrap();
        s.set_nodelay(true).unwrap();
        let start = Instant::now();
        for b in &framed {
            s.write_all(std::slice::from_ref(b)).unwrap();
            std::thread::sleep(Duration::from_millis(10));
        }
        let mut frame = Vec::new();
        protocol::read_frame_into(&mut s, &mut frame).unwrap();
        (frame, start.elapsed())
    });

    std::thread::sleep(Duration::from_millis(30));
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let ping = Request::Ping.encode();
    let start = Instant::now();
    let mut frame = Vec::new();
    for _ in 0..30 {
        protocol::write_frame(&mut stream, &ping).unwrap();
        protocol::read_frame_into(&mut reader, &mut frame).unwrap();
        assert_eq!(Response::decode(&frame).unwrap(), Response::Pong);
    }
    let fast_elapsed = start.elapsed();

    let (slow_frame, slow_elapsed) = slow.join().unwrap();
    assert_eq!(
        Response::decode(&slow_frame).unwrap(),
        Response::Registered { id: "slow".into() }
    );
    assert!(
        fast_elapsed < slow_elapsed / 2,
        "30 fast round trips took {fast_elapsed:?} against a {slow_elapsed:?} slowloris"
    );
}

/// Idle disconnect (the reactor now honors `--conn-timeout-ms` via its
/// coarse sweep): an idle connection is closed after the timeout while
/// a connection that keeps pipelining requests through the same window
/// is left alone.
#[test]
fn serve_reactor_idle_timeout_closes_idle_but_not_active() {
    let (addr, _h) = spawn_server_cfg(ServerMode::Reactor, |c| {
        c.conn_timeout = Some(Duration::from_millis(300));
    });

    // The idle connection: sends one ping (so it's fully established
    // and counted), then goes quiet.
    let mut idle = TcpStream::connect(&addr).unwrap();
    idle.set_nodelay(true).unwrap();
    let mut idle_reader = BufReader::new(idle.try_clone().unwrap());
    let ping = Request::Ping.encode();
    let mut frame = Vec::new();
    protocol::write_frame(&mut idle, &ping).unwrap();
    protocol::read_frame_into(&mut idle_reader, &mut frame).unwrap();

    // The active connection pings through the whole idle window.
    let mut active = TcpStream::connect(&addr).unwrap();
    active.set_nodelay(true).unwrap();
    let mut active_reader = BufReader::new(active.try_clone().unwrap());
    for _ in 0..15 {
        protocol::write_frame(&mut active, &ping).unwrap();
        protocol::read_frame_into(&mut active_reader, &mut frame).unwrap();
        assert_eq!(Response::decode(&frame).unwrap(), Response::Pong);
        std::thread::sleep(Duration::from_millis(100));
    }

    // 1.5 s of activity has passed — the idle peer must be gone (EOF,
    // not a hang; the sweep runs off the epoll timeout, so give it
    // slack but bound the wait).
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 16];
    assert_eq!(
        idle.read(&mut buf).unwrap(),
        0,
        "idle connection should be closed by the timeout sweep"
    );

    // The active connection survived the sweep.
    protocol::write_frame(&mut active, &ping).unwrap();
    protocol::read_frame_into(&mut active_reader, &mut frame).unwrap();
    assert_eq!(Response::decode(&frame).unwrap(), Response::Pong);
}

/// Cooperative shutdown: tripping the flag makes every loop close its
/// connections, the workers join, and `serve` itself returns `Ok` —
/// no leaked threads, no error, and the port stops accepting.
#[test]
fn serve_shutdown_joins_all_loops_and_workers() {
    let flag = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let (addr, handle) = spawn_server_cfg(ServerMode::Reactor, {
        let flag = flag.clone();
        move |c| {
            c.reactor_threads = 4;
            c.reactor_workers = 2;
            c.shutdown = Some(flag);
        }
    });

    // The server works before the trip.
    let mut c = SketchClient::connect(&addr).unwrap();
    c.ping().unwrap();
    let st = c.stats_detailed().unwrap();
    assert_eq!(
        st.reactor.expect("reactor section").per_loop.len(),
        4,
        "all four loops came up"
    );

    flag.store(true, std::sync::atomic::Ordering::SeqCst);
    // Loops poll with a finite timeout when a shutdown flag is set, so
    // the whole front-end (loops + workers) joins promptly and clean.
    handle
        .join()
        .expect("serve thread must not panic")
        .expect("cooperative shutdown must return Ok");

    // Our pre-shutdown connection was closed by the drain, and the
    // listeners are gone: a fresh connect must fail outright or be
    // reset before answering.
    let dead = TcpStream::connect(&addr).and_then(|mut s| {
        s.set_read_timeout(Some(Duration::from_secs(2)))?;
        protocol::write_frame(&mut s, &Request::Ping.encode())?;
        let mut buf = [0u8; 4];
        match s.read(&mut buf) {
            Ok(0) => Err(std::io::Error::new(std::io::ErrorKind::ConnectionAborted, "eof")),
            Ok(_) => Ok(()),
            Err(e) => Err(e),
        }
    });
    assert!(dead.is_err(), "the shut-down server must stop answering");
}
