//! Fault-injection harness for WAL-shipping replication: snapshot
//! bootstrap + catch-up, `kill -9` the primary and promote the replica
//! (byte-identical to a restarted primary), a TCP proxy shim that
//! truncates / drops / delays the replication stream (the replica must
//! reconnect with bounded backoff and never apply a torn record), and
//! the lag-cap path where the primary retires WAL a slow replica still
//! needs and the replica re-bootstraps from a fresh snapshot.
//!
//! Run standalone with `cargo test --release -q replication` (CI does).

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crp::coordinator::durability::DurabilityConfig;
use crp::coordinator::maintenance::MaintenanceConfig;
use crp::coordinator::protocol::{Request, Response};
use crp::coordinator::server::{serve, ServerConfig, ServiceState};
use crp::coordinator::store::SketchStore;
use crp::coordinator::{FsyncPolicy, SketchClient};
use crp::mathx::Pcg64;
use crp::projection::{ProjectionConfig, Projector};

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("crp_repl_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn projector(k: usize) -> Arc<Projector> {
    Arc::new(Projector::new_cpu(ProjectionConfig {
        k,
        seed: 7,
        ..Default::default()
    }))
}

/// Primary config: durable `default` collection, explicit checkpoints
/// only, no background maintenance cadence — deterministic WAL growth.
fn primary_cfg(dir: &Path) -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".into(),
        durability: Some(DurabilityConfig {
            snapshot: dir.join("snapshot.bin"),
            wal_dir: dir.join("wal"),
            checkpoint_every: 0,
            fsync: FsyncPolicy::Os,
        }),
        maintenance: MaintenanceConfig {
            tick: Duration::from_secs(60),
        },
        ..Default::default()
    }
}

/// Replica config pulling from `primary` — in-memory (replication
/// forbids local durability), tight poll/backoff so tests converge
/// fast.
fn replica_cfg(primary: &str) -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".into(),
        replicate_from: Some(primary.to_string()),
        repl_poll: Duration::from_millis(10),
        repl_backoff_min: Duration::from_millis(10),
        repl_backoff_max: Duration::from_millis(100),
        ..Default::default()
    }
}

fn spawn_server(cfg: ServerConfig, k: usize) -> String {
    let projector = projector(k);
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = serve(projector, cfg, Some(tx));
    });
    rx.recv()
        .expect("server thread exited before reporting its bound address")
        .to_string()
}

fn vec_of(g: &mut Pcg64, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| g.next_f64() as f32 - 0.5).collect()
}

/// Sorted `(id, raw words)` dump — the byte-for-byte comparison basis.
fn dump(store: &SketchStore) -> Vec<(String, Vec<u64>)> {
    let mut out = Vec::new();
    store.for_each(|id, codes| out.push((id.to_string(), codes.words().to_vec())));
    out.sort();
    out
}

/// Wait until `pred` holds or the deadline trips (fail with `what`).
fn wait_for(what: &str, timeout: Duration, mut pred: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !pred() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Block until the replica has bootstrapped and drained its lag to
/// zero with `rows` rows visible.
fn wait_caught_up(replica: &ServiceState, rows: usize, what: &str) {
    let state = replica.replica.as_ref().expect("replica state").clone();
    let store = replica.store.clone();
    wait_for(what, Duration::from_secs(30), move || {
        state.ready() && state.lag_bytes() == 0 && state.lag_records() == 0 && store.len() == rows
    });
}

// ---------------------------------------------------------------------
// Fault-injection proxy
// ---------------------------------------------------------------------

/// Shared dials for the proxy; flipped mid-test to inject faults.
struct ProxyCtl {
    /// Truncate: kill a connection after this many primary→replica
    /// bytes (0 = unlimited). Odd values land mid-frame on purpose.
    cut_after: AtomicU64,
    /// Blackhole: drop every active connection and refuse new ones
    /// while set (a flapping network / dead primary).
    drop_all: AtomicBool,
    /// Latency injected per primary→replica read, in milliseconds.
    delay_ms: AtomicU64,
    /// Connections accepted so far (counts reconnect attempts).
    conns: AtomicU64,
}

impl ProxyCtl {
    fn new() -> Arc<ProxyCtl> {
        Arc::new(ProxyCtl {
            cut_after: AtomicU64::new(0),
            drop_all: AtomicBool::new(false),
            delay_ms: AtomicU64::new(0),
            conns: AtomicU64::new(0),
        })
    }
}

/// A TCP shim between replica and primary that can truncate, drop, and
/// delay the stream. Dropping the proxy stops the accept loop.
struct Proxy {
    addr: SocketAddr,
    ctl: Arc<ProxyCtl>,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Proxy {
    fn spawn(upstream: String) -> Proxy {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let ctl = ProxyCtl::new();
        let stop = Arc::new(AtomicBool::new(false));
        let (ctl2, stop2) = (ctl.clone(), stop.clone());
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((down, _)) => {
                        if ctl2.drop_all.load(Ordering::Relaxed) {
                            drop(down); // refused: network is down
                            continue;
                        }
                        ctl2.conns.fetch_add(1, Ordering::Relaxed);
                        let Ok(up) = TcpStream::connect(&upstream) else {
                            continue;
                        };
                        pump_pair(down, up, ctl2.clone(), stop2.clone());
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Proxy {
            addr,
            ctl,
            stop,
            handle: Some(handle),
        }
    }

    fn addr(&self) -> String {
        self.addr.to_string()
    }
}

impl Drop for Proxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Two pump threads per connection; either side closing (or a fault
/// dial firing) shuts the whole pair down so the replica sees a clean
/// stream loss, never a hang.
fn pump_pair(down: TcpStream, up: TcpStream, ctl: Arc<ProxyCtl>, stop: Arc<AtomicBool>) {
    let (d2, u2) = (down.try_clone().unwrap(), up.try_clone().unwrap());
    // replica → primary: requests, forwarded verbatim.
    {
        let (ctl, stop) = (ctl.clone(), stop.clone());
        std::thread::spawn(move || pump(down, up, ctl, stop, false));
    }
    // primary → replica: responses, where truncation and delay bite.
    std::thread::spawn(move || pump(u2, d2, ctl, stop, true));
}

fn pump(
    mut from: TcpStream,
    mut to: TcpStream,
    ctl: Arc<ProxyCtl>,
    stop: Arc<AtomicBool>,
    faulted: bool,
) {
    from.set_read_timeout(Some(Duration::from_millis(30))).unwrap();
    let close = |a: &TcpStream, b: &TcpStream| {
        let _ = a.shutdown(Shutdown::Both);
        let _ = b.shutdown(Shutdown::Both);
    };
    let mut sent = 0u64;
    let mut buf = [0u8; 4096];
    loop {
        if stop.load(Ordering::Relaxed) || ctl.drop_all.load(Ordering::Relaxed) {
            close(&from, &to);
            return;
        }
        let n = match from.read(&mut buf) {
            Ok(0) => {
                close(&from, &to);
                return;
            }
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => {
                close(&from, &to);
                return;
            }
        };
        if faulted {
            let delay = ctl.delay_ms.load(Ordering::Relaxed);
            if delay > 0 {
                std::thread::sleep(Duration::from_millis(delay));
            }
            let cut = ctl.cut_after.load(Ordering::Relaxed);
            if cut > 0 {
                // Forward only up to the byte budget, then sever both
                // directions — a mid-frame truncation.
                let left = cut.saturating_sub(sent) as usize;
                if left < n {
                    let _ = to.write_all(&buf[..left]);
                    close(&from, &to);
                    return;
                }
            }
        }
        sent += n as u64;
        if to.write_all(&buf[..n]).is_err() {
            close(&from, &to);
            return;
        }
    }
}

// ---------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------

/// The acceptance pin: a replica bootstrapped from a live primary and
/// caught up through mid-ingest writes, then promoted after the
/// primary "dies", answers Knn/TopK/ApproxTopK/Estimate byte-
/// identically to a primary restarted from disk (`kill -9` semantics:
/// state rebuilt from snapshot + WAL with no graceful shutdown).
#[test]
fn replication_kill9_promote_equals_restarted_primary() {
    let dir = temp_dir("kill9");
    let p_cfg = primary_cfg(&dir);
    let p_addr = spawn_server(p_cfg.clone(), 128);
    let mut client = SketchClient::connect_with_retry(&p_addr, 5).unwrap();
    let mut g = Pcg64::new(0xFA11, 0);

    // Acked writes before the replica exists: singles + bulk + removes.
    for i in 0..80 {
        client.register(&format!("v{i:03}"), vec_of(&mut g, 24)).unwrap();
    }
    let ids: Vec<String> = (0..40).map(|i| format!("b{i:02}")).collect();
    let vectors: Vec<Vec<f32>> = (0..40).map(|_| vec_of(&mut g, 24)).collect();
    assert_eq!(client.register_batch_in(None, ids, vectors).unwrap(), 40);
    for i in (0..30).step_by(3) {
        client.remove(&format!("v{i:03}")).unwrap();
    }

    // Replica comes up cold: snapshot bootstrap, then WAL tail.
    let replica = ServiceState::open(projector(128), &replica_cfg(&p_addr)).unwrap();
    wait_caught_up(&replica, 110, "initial bootstrap + catch-up");
    let r_state = replica.replica.as_ref().unwrap().clone();
    assert!(r_state.bootstraps() >= 1);

    // Mid-ingest: more acked writes (overwrites included) while the
    // replica tails the WAL.
    for i in 0..40 {
        client.register(&format!("w{i:03}"), vec_of(&mut g, 24)).unwrap();
    }
    client.register("v001", vec_of(&mut g, 24)).unwrap(); // overwrite
    client.remove("b07").unwrap();
    wait_caught_up(&replica, 149, "mid-ingest catch-up");

    // kill -9: rebuild a primary purely from disk while the original
    // process is still alive — exactly a crashed primary's leftovers.
    let restarted = ServiceState::open(projector(128), &p_cfg).unwrap();
    assert_eq!(dump(&replica.store), dump(&restarted.store));

    // Fail over: the replica becomes the writable primary.
    match replica.handle(Request::Promote) {
        Response::Promoted { was_replica } => assert!(was_replica),
        other => panic!("unexpected {other:?}"),
    }

    // Every read path answers byte-identically.
    for q in 0..5 {
        let v = vec_of(&mut g, 24);
        assert_eq!(
            replica.handle(Request::Knn {
                vector: v.clone(),
                n: 10
            }),
            restarted.handle(Request::Knn { vector: v, n: 10 }),
            "knn query {q}"
        );
    }
    let batch: Vec<Vec<f32>> = (0..4).map(|_| vec_of(&mut g, 24)).collect();
    assert_eq!(
        replica.handle(Request::TopK {
            vectors: batch.clone(),
            n: 5
        }),
        restarted.handle(Request::TopK {
            vectors: batch.clone(),
            n: 5
        })
    );
    assert_eq!(
        replica.handle(Request::ApproxTopK {
            vectors: batch.clone(),
            n: 5,
            probes: 2
        }),
        restarted.handle(Request::ApproxTopK {
            vectors: batch,
            n: 5,
            probes: 2
        })
    );
    for (a, b) in [("v001", "v002"), ("b00", "b39"), ("w000", "v050")] {
        assert_eq!(
            replica.handle(Request::Estimate {
                a: a.into(),
                b: b.into()
            }),
            restarted.handle(Request::Estimate {
                a: a.into(),
                b: b.into()
            }),
            "{a}/{b}"
        );
    }

    // Promoted: writes are accepted again.
    match replica.handle(Request::Register {
        id: "post-failover".into(),
        vector: vec_of(&mut g, 24),
    }) {
        Response::Registered { .. } => {}
        other => panic!("unexpected {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The harness proper: the replication stream runs through a proxy
/// that truncates responses mid-frame, blackholes the link, and
/// injects latency. The replica must reconnect with bounded backoff,
/// never apply a torn record, and converge to the primary's exact
/// byte state once the network heals.
#[test]
fn replication_rides_out_truncation_drops_and_flapping() {
    let dir = temp_dir("faults");
    let p_cfg = primary_cfg(&dir);
    let p_addr = spawn_server(p_cfg.clone(), 64);
    let mut client = SketchClient::connect_with_retry(&p_addr, 5).unwrap();
    let mut g = Pcg64::new(0xBAD, 1);
    for i in 0..120 {
        client.register(&format!("v{i:03}"), vec_of(&mut g, 16)).unwrap();
    }

    let proxy = Proxy::spawn(p_addr.clone());
    // Phase 1: every primary→replica stream dies after ~600 bytes —
    // mid-bootstrap, mid-frame. The replica must keep retrying.
    proxy.ctl.cut_after.store(600, Ordering::Relaxed);
    let replica = ServiceState::open(projector(64), &replica_cfg(&proxy.addr())).unwrap();
    let ctl = proxy.ctl.clone();
    wait_for("several truncated attempts", Duration::from_secs(30), || {
        ctl.conns.load(Ordering::Relaxed) >= 4
    });
    // Torn transfers must never leak partial state into the store.
    assert_eq!(replica.store.len(), 0, "torn bootstrap must apply nothing");

    // Heal: the very same replica (no restart) bootstraps and catches
    // up through reconnect + backoff alone.
    proxy.ctl.cut_after.store(0, Ordering::Relaxed);
    wait_caught_up(&replica, 120, "catch-up after truncation heals");

    // Phase 2: latency only — a slow network is not a fault.
    proxy.ctl.delay_ms.store(20, Ordering::Relaxed);
    for i in 0..20 {
        client.register(&format!("s{i:02}"), vec_of(&mut g, 16)).unwrap();
    }
    wait_caught_up(&replica, 140, "catch-up through injected latency");
    proxy.ctl.delay_ms.store(0, Ordering::Relaxed);

    // Phase 3: a flapping network — repeated blackhole windows with
    // acked writes landing while the link is down.
    for round in 0..3usize {
        proxy.ctl.drop_all.store(true, Ordering::Relaxed);
        for i in 0..10 {
            client
                .register(&format!("f{round}{i:02}"), vec_of(&mut g, 16))
                .unwrap();
        }
        std::thread::sleep(Duration::from_millis(150));
        proxy.ctl.drop_all.store(false, Ordering::Relaxed);
        wait_caught_up(&replica, 140 + (round + 1) * 10, "catch-up after flap");
    }
    let r_state = replica.replica.as_ref().unwrap();
    assert!(
        r_state.reconnects() >= 3,
        "flapping must surface as reconnects (saw {})",
        r_state.reconnects()
    );

    // Convergence is byte-exact against the primary's durable state.
    let restarted = ServiceState::open(projector(64), &p_cfg).unwrap();
    assert_eq!(dump(&replica.store), dump(&restarted.store));
    std::fs::remove_dir_all(&dir).ok();
}

/// The lag-cap path: a replica that falls behind further than
/// `--repl-lag-cap` loses its WAL position (the primary retires the
/// pinned segments rather than hoard unbounded log) and must recover
/// by re-bootstrapping from a fresh snapshot — automatically.
#[test]
fn replication_lag_cap_forces_rebootstrap() {
    let dir = temp_dir("lagcap");
    let mut p_cfg = primary_cfg(&dir);
    p_cfg.repl_lag_cap = 4096; // tiny: a few hundred records overflow it
    let p_addr = spawn_server(p_cfg.clone(), 64);
    let mut client = SketchClient::connect_with_retry(&p_addr, 5).unwrap();
    let mut g = Pcg64::new(0xCAB, 2);
    for i in 0..50 {
        client.register(&format!("v{i:03}"), vec_of(&mut g, 16)).unwrap();
    }

    let proxy = Proxy::spawn(p_addr.clone());
    let mut r_cfg = replica_cfg(&proxy.addr());
    r_cfg.repl_lag_cap = 4096;
    let replica = ServiceState::open(projector(64), &r_cfg).unwrap();
    wait_caught_up(&replica, 50, "initial catch-up");
    let r_state = replica.replica.as_ref().unwrap().clone();
    let initial_bootstraps = r_state.bootstraps();
    assert!(initial_bootstraps >= 1);

    // Cut the link, then push far more WAL than the cap allows and
    // checkpoint: the primary must retire the replica's pinned
    // segments instead of holding unbounded log.
    proxy.ctl.drop_all.store(true, Ordering::Relaxed);
    for i in 0..600 {
        client.register(&format!("z{i:04}"), vec_of(&mut g, 16)).unwrap();
    }
    client.persist().unwrap(); // checkpoint → rotate + gated retire

    // Heal: the replica's resume position is gone; the primary answers
    // with a bootstrap in the same round trip and the replica rebuilds.
    proxy.ctl.drop_all.store(false, Ordering::Relaxed);
    wait_caught_up(&replica, 650, "re-bootstrap past the lag cap");
    assert!(
        r_state.bootstraps() > initial_bootstraps,
        "a lag-capped replica must re-bootstrap (still {} bootstrap(s))",
        r_state.bootstraps()
    );

    let restarted = ServiceState::open(projector(64), &p_cfg).unwrap();
    assert_eq!(dump(&replica.store), dump(&restarted.store));
    std::fs::remove_dir_all(&dir).ok();
}

/// A replica served over real TCP: answers reads, rejects writes with
/// a redirect to the primary, reports lag through `StatsDetailed`, and
/// flips writable on `crp promote` — plus /healthz and /readyz on the
/// metrics listener.
#[test]
fn replica_over_tcp_serves_reads_rejects_writes_and_promotes() {
    let dir = temp_dir("tcp");
    let p_addr = spawn_server(primary_cfg(&dir), 64);
    let mut p_client = SketchClient::connect_with_retry(&p_addr, 5).unwrap();
    let mut g = Pcg64::new(0x7C9, 3);
    for i in 0..50 {
        p_client.register(&format!("v{i:03}"), vec_of(&mut g, 16)).unwrap();
    }

    // Pick a port for the replica's metrics/health listener (bind :0,
    // note the port, release it — the tiny reuse race is acceptable in
    // tests).
    let metrics_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let mut r_cfg = replica_cfg(&p_addr);
    r_cfg.metrics_addr = Some(metrics_addr.clone());
    let r_addr = spawn_server(r_cfg, 64);
    let mut r_client = SketchClient::connect_with_retry(&r_addr, 5).unwrap();

    // Reads always answered; writes rejected with the redirect.
    r_client.ping().unwrap();
    let err = r_client
        .register("nope", vec_of(&mut g, 16))
        .expect_err("replica must reject writes")
        .to_string();
    assert!(err.contains("read-only"), "{err}");
    assert!(err.contains(&p_addr), "redirect must name the primary: {err}");
    assert!(err.contains("promote"), "{err}");

    // Catch-up is observable through the replication stats tail.
    wait_for("replica catch-up over TCP", Duration::from_secs(30), || {
        let st = r_client.stats_detailed().unwrap();
        let caught = st.per_collection.iter().any(|c| c.rows == 50);
        let r = st.replication.expect("replica must report replication");
        assert!(r.active);
        assert_eq!(r.primary, p_addr);
        caught && r.lag_bytes == 0 && r.lag_records == 0
    });
    // A caught-up replica answers the same top hit as the primary.
    let q = vec_of(&mut g, 16);
    assert_eq!(
        r_client.knn(q.clone(), 5).unwrap(),
        p_client.knn(q, 5).unwrap()
    );

    // Health endpoints on the metrics listener.
    let http_get = |path: &str| -> String {
        let mut s = TcpStream::connect(&metrics_addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };
    assert!(http_get("/healthz").starts_with("HTTP/1.1 200 OK"));
    let ready = http_get("/readyz");
    assert!(ready.starts_with("HTTP/1.1 200 OK"), "{ready}");
    assert!(ready.contains("replica of"), "{ready}");
    let page = http_get("/metrics");
    assert!(page.contains("crp_replication_lag_bytes 0"), "missing lag gauge");
    assert!(page.contains("crp_replication_active 1"), "missing active gauge");

    // Promote over TCP: writes start succeeding, idempotently.
    assert!(r_client.promote().unwrap());
    r_client.register("post-promote", vec_of(&mut g, 16)).unwrap();
    assert!(!r_client.promote().unwrap(), "second promote is a no-op");
    let still_ready = http_get("/readyz");
    assert!(still_ready.starts_with("HTTP/1.1 200 OK"), "{still_ready}");
    std::fs::remove_dir_all(&dir).ok();
}
