//! Multi-collection serving tests: cross-collection isolation (same ids
//! never collide, per-collection coding enforced with clean errors),
//! multi-collection `kill -9` recovery via the MANIFEST, safe directory
//! reuse across create→ingest→drop→re-create, the namespaced client
//! over TCP, and the `--max-conns` accept-loop bound.
//!
//! Run standalone with `cargo test --release -q collections` (CI does).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crp::coding::Scheme;
use crp::coordinator::maintenance::MaintenanceConfig;
use crp::coordinator::protocol::{Request, Response};
use crp::coordinator::server::{serve, ServerConfig, ServiceState};
use crp::coordinator::store::SketchStore;
use crp::coordinator::SketchClient;
use crp::mathx::Pcg64;
use crp::projection::{MatrixKind, ProjectionConfig, Projector};

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("crp_collections_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn projector(k: usize) -> Arc<Projector> {
    Arc::new(Projector::new_cpu(ProjectionConfig {
        k,
        seed: 7,
        ..Default::default()
    }))
}

fn vec_of(g: &mut Pcg64, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| g.next_f64() as f32 - 0.5).collect()
}

/// Sorted `(id, raw words)` dump — the byte-for-byte comparison basis.
fn dump(store: &SketchStore) -> Vec<(String, Vec<u64>)> {
    let mut out = Vec::new();
    store.for_each(|id, codes| out.push((id.to_string(), codes.words().to_vec())));
    out.sort();
    out
}

fn scoped(collection: &str, inner: Request) -> Request {
    Request::Scoped {
        collection: collection.to_string(),
        inner: Box::new(inner),
    }
}

fn register(state: &ServiceState, collection: Option<&str>, id: &str, vector: Vec<f32>) {
    let req = Request::Register {
        id: id.to_string(),
        vector,
    };
    let req = match collection {
        Some(c) => scoped(c, req),
        None => req,
    };
    match state.handle(req) {
        Response::Registered { .. } => {}
        other => panic!("register {id:?} in {collection:?}: unexpected {other:?}"),
    }
}

fn knn_ids(
    state: &ServiceState,
    collection: Option<&str>,
    vector: Vec<f32>,
    n: u32,
) -> Vec<String> {
    let req = Request::Knn { vector, n };
    let req = match collection {
        Some(c) => scoped(c, req),
        None => req,
    };
    match state.handle(req) {
        Response::Knn { hits } => hits.into_iter().map(|h| h.id).collect(),
        other => panic!("knn in {collection:?}: unexpected {other:?}"),
    }
}

/// The acceptance pin: one process serves two collections with
/// different `(scheme, bits)` — `default` two-bit/0.75 (2 bits) and a
/// uniform/w=1.0 (4 bits) — with fully isolated rows and rankings.
#[test]
fn collections_isolate_same_ids_across_schemes() {
    let state = ServiceState::open(projector(256), &ServerConfig::default()).unwrap();
    match state.handle(Request::CreateCollection {
        name: "u4".into(),
        scheme: Scheme::Uniform,
        w: 1.0,
        bits: 4,
        k: 128,
        seed: 11,
        checkpoint_every: 0,
        kind: MatrixKind::Gaussian,
    }) {
        Response::CollectionCreated { name } => assert_eq!(name, "u4"),
        other => panic!("unexpected {other:?}"),
    }
    let u4 = state.registry.get("u4").unwrap();
    assert_eq!(u4.spec.bits(), 4);
    assert_eq!(state.default.spec.bits(), 2);

    let mut g = Pcg64::new(0xC0FFEE, 0);
    for i in 0..30 {
        register(&state, None, &format!("d{i:02}"), vec_of(&mut g, 48));
        register(&state, Some("u4"), &format!("u{i:02}"), vec_of(&mut g, 48));
    }
    // The same id in both collections, with different vectors.
    let (shared_d, shared_u) = (vec_of(&mut g, 48), vec_of(&mut g, 48));
    register(&state, None, "x", shared_d.clone());
    register(&state, Some("u4"), "x", shared_u);
    assert_eq!(state.default.store.len(), 31);
    assert_eq!(u4.store.len(), 31);
    // Isolated sketches: same id, different shapes entirely.
    assert_ne!(
        state.default.store.get("x"),
        u4.store.get("x"),
        "same id must not collide across collections"
    );

    // Knn in each collection only ever surfaces its own ids.
    let q = vec_of(&mut g, 48);
    let d_hits = knn_ids(&state, None, q.clone(), 10);
    assert_eq!(d_hits.len(), 10);
    assert!(
        d_hits.iter().all(|id| id.starts_with('d') || id == "x"),
        "{d_hits:?}"
    );
    let u_hits = knn_ids(&state, Some("u4"), q.clone(), 10);
    assert_eq!(u_hits.len(), 10);
    assert!(
        u_hits.iter().all(|id| id.starts_with('u') || id == "x"),
        "{u_hits:?}"
    );
    // Scoped-to-default ≡ legacy unscoped, byte-identically.
    assert_eq!(
        state.handle(Request::Knn {
            vector: q.clone(),
            n: 10
        }),
        state.handle(scoped(
            "default",
            Request::Knn {
                vector: q.clone(),
                n: 10
            }
        ))
    );
    // Batched TopK respects the namespace too.
    match state.handle(scoped(
        "u4",
        Request::TopK {
            vectors: vec![q.clone()],
            n: 10,
        },
    )) {
        Response::TopK { results } => {
            let ids: Vec<String> = results[0].iter().map(|h| h.id.clone()).collect();
            assert_eq!(ids, u_hits, "TopK must rank exactly like Knn per collection");
        }
        other => panic!("unexpected {other:?}"),
    }

    // Removing the shared id from one collection leaves the other.
    match state.handle(scoped("u4", Request::Remove { id: "x".into() })) {
        Response::Removed { existed } => assert!(existed),
        other => panic!("unexpected {other:?}"),
    }
    assert!(u4.store.get("x").is_none());
    assert_eq!(state.default.store.get("x"), state.store.get("x"));
    assert!(state.default.store.get("x").is_some());

    // Estimates stay collection-local: "x" is gone from u4 only.
    match state.handle(scoped(
        "u4",
        Request::Estimate {
            a: "x".into(),
            b: "u00".into(),
        },
    )) {
        Response::Error { message } => assert!(message.contains('x'), "{message}"),
        other => panic!("unexpected {other:?}"),
    }
    match state.handle(Request::Estimate {
        a: "x".into(),
        b: "d00".into(),
    }) {
        Response::Estimate { .. } => {}
        other => panic!("unexpected {other:?}"),
    }
}

/// Clean errors, not panics, for every malformed collection operation.
#[test]
fn collections_shape_and_name_errors_are_clean() {
    let state = ServiceState::open(projector(64), &ServerConfig::default()).unwrap();
    let cases: Vec<(Request, &str)> = vec![
        (
            Request::CreateCollection {
                name: "bad/name".into(),
                scheme: Scheme::OneBit,
                w: 0.0,
                bits: 0,
                k: 32,
                seed: 0,
                checkpoint_every: 0,
                kind: MatrixKind::Gaussian,
            },
            "characters",
        ),
        (
            Request::CreateCollection {
                name: "default".into(),
                scheme: Scheme::OneBit,
                w: 0.0,
                bits: 0,
                k: 32,
                seed: 0,
                checkpoint_every: 0,
                kind: MatrixKind::Gaussian,
            },
            "already exists",
        ),
        (
            Request::CreateCollection {
                name: "MANIFEST".into(),
                scheme: Scheme::OneBit,
                w: 0.0,
                bits: 0,
                k: 32,
                seed: 0,
                checkpoint_every: 0,
                kind: MatrixKind::Gaussian,
            },
            "reserved",
        ),
        (
            Request::CreateCollection {
                name: "w0".into(),
                scheme: Scheme::Uniform,
                w: 0.0,
                bits: 0,
                k: 32,
                seed: 0,
                checkpoint_every: 0,
                kind: MatrixKind::Gaussian,
            },
            "bin width",
        ),
        (
            Request::CreateCollection {
                name: "k0".into(),
                scheme: Scheme::OneBit,
                w: 0.0,
                bits: 0,
                k: 0,
                seed: 0,
                checkpoint_every: 0,
                kind: MatrixKind::Gaussian,
            },
            "outside",
        ),
        (
            Request::CreateCollection {
                name: "b3".into(),
                scheme: Scheme::TwoBit,
                w: 0.75,
                bits: 3,
                k: 32,
                seed: 0,
                checkpoint_every: 0,
                kind: MatrixKind::Gaussian,
            },
            "2 bit",
        ),
        (
            Request::DropCollection {
                name: "default".into(),
            },
            "default",
        ),
        (
            scoped(
                "ghost",
                Request::Register {
                    id: "a".into(),
                    vector: vec![1.0; 8],
                },
            ),
            "unknown collection",
        ),
        (
            scoped(
                "ghost",
                Request::TopK {
                    vectors: vec![vec![1.0; 8]],
                    n: 1,
                },
            ),
            "unknown collection",
        ),
    ];
    for (req, needle) in cases {
        match state.handle(req.clone()) {
            Response::Error { message } => {
                assert!(message.contains(needle), "{req:?} → {message:?}")
            }
            other => panic!("{req:?}: unexpected {other:?}"),
        }
    }
    // Only `default` exists after all the failed creates.
    match state.handle(Request::ListCollections) {
        Response::Collections { collections } => {
            assert_eq!(collections.len(), 1);
            assert_eq!(collections[0].name, "default");
        }
        other => panic!("unexpected {other:?}"),
    }
}

fn data_dir_cfg(dir: &Path) -> ServerConfig {
    ServerConfig {
        data_dir: Some(dir.to_path_buf()),
        checkpoint_every: 0, // explicit Persist only — keeps tests deterministic
        maintenance: MaintenanceConfig {
            tick: Duration::from_secs(60),
        },
        ..Default::default()
    }
}

/// The acceptance pin: a server with two extra collections (different
/// schemes and bit widths), seeded with singles + bulk + removes and
/// checkpointed at an arbitrary point, is "killed" (state rebuilt from
/// disk via MANIFEST + per-collection snapshot/WAL, no graceful
/// shutdown) and answers byte-identically on every collection.
#[test]
fn collections_kill9_recovery_via_manifest() {
    let dir = temp_dir("kill9");
    let cfg = data_dir_cfg(&dir);
    let live = ServiceState::open(projector(256), &cfg).unwrap();
    for (name, scheme, w, k, seed) in [
        ("two", Scheme::TwoBit, 0.75, 96u64, 5u64),
        ("uni4", Scheme::Uniform, 1.0, 128, 11),
    ] {
        match live.handle(Request::CreateCollection {
            name: name.into(),
            scheme,
            w,
            bits: 0,
            k,
            seed,
            checkpoint_every: 0,
            kind: MatrixKind::Gaussian,
        }) {
            Response::CollectionCreated { .. } => {}
            other => panic!("create {name}: unexpected {other:?}"),
        }
    }
    let names = ["default", "two", "uni4"];
    let mut g = Pcg64::new(99, 0);
    // Singles into every collection.
    for i in 0..40 {
        for name in &names {
            register(&live, Some(name), &format!("v{i:02}"), vec_of(&mut g, 40));
        }
    }
    // One bulk batch per collection.
    for name in &names {
        let ids: Vec<String> = (0..20).map(|i| format!("b{i:02}")).collect();
        let vectors: Vec<Vec<f32>> = (0..20).map(|_| vec_of(&mut g, 40)).collect();
        match live.handle(scoped(name, Request::RegisterBatch { ids, vectors })) {
            Response::RegisteredBatch { count } => assert_eq!(count, 20),
            other => panic!("bulk {name}: unexpected {other:?}"),
        }
    }
    // Removes, then a checkpoint of ONE collection at an arbitrary
    // point, then more mutations everywhere.
    for i in (0..30).step_by(3) {
        for name in &names {
            match live.handle(scoped(
                name,
                Request::Remove {
                    id: format!("v{i:02}"),
                },
            )) {
                Response::Removed { existed } => assert!(existed),
                other => panic!("unexpected {other:?}"),
            }
        }
    }
    match live.handle(scoped("uni4", Request::Persist)) {
        Response::Persisted { rows, .. } => assert_eq!(rows, 50),
        other => panic!("unexpected {other:?}"),
    }
    for name in &names {
        register(&live, Some(name), "v01", vec_of(&mut g, 40)); // overwrite
        register(&live, Some(name), "post", vec_of(&mut g, 40)); // fresh
        match live.handle(scoped(name, Request::Remove { id: "b03".into() })) {
            Response::Removed { existed } => assert!(existed),
            other => panic!("unexpected {other:?}"),
        }
    }

    // kill -9: rebuild purely from disk while the first instance is
    // still alive — nothing graceful has run.
    let restarted = ServiceState::open(projector(256), &cfg).unwrap();
    assert_eq!(restarted.registry.len(), 3, "MANIFEST must list all three");
    for name in &names {
        let a = live.registry.get(name).unwrap();
        let b = restarted.registry.get(name).unwrap();
        assert_eq!(a.spec, b.spec, "{name}: spec must survive via MANIFEST");
        assert_eq!(dump(&a.store), dump(&b.store), "{name}: byte-for-byte");
        // Byte-identical responses on every read path, per collection.
        for _ in 0..3 {
            let v = vec_of(&mut g, 40);
            assert_eq!(
                live.handle(scoped(
                    name,
                    Request::Knn {
                        vector: v.clone(),
                        n: 10
                    }
                )),
                restarted.handle(scoped(name, Request::Knn { vector: v, n: 10 })),
                "{name}"
            );
        }
        let batch: Vec<Vec<f32>> = (0..3).map(|_| vec_of(&mut g, 40)).collect();
        assert_eq!(
            live.handle(scoped(
                name,
                Request::TopK {
                    vectors: batch.clone(),
                    n: 5
                }
            )),
            restarted.handle(scoped(name, Request::TopK { vectors: batch, n: 5 })),
            "{name}"
        );
        assert_eq!(
            live.handle(scoped(
                name,
                Request::Estimate {
                    a: "v01".into(),
                    b: "post".into()
                }
            )),
            restarted.handle(scoped(
                name,
                Request::Estimate {
                    a: "v01".into(),
                    b: "post".into()
                }
            )),
            "{name}"
        );
    }
    assert_eq!(
        live.handle(Request::ListCollections),
        restarted.handle(Request::ListCollections)
    );

    // Restarting with contradicting default flags is an error, not
    // silent drift.
    let bad = ServerConfig {
        coding: crp::coding::CodingParams::new(Scheme::OneBit, 0.0),
        ..data_dir_cfg(&dir)
    };
    let err = ServiceState::open(projector(256), &bad)
        .err()
        .expect("flag drift must be rejected")
        .to_string();
    assert!(err.contains("default"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// create → ingest → drop → re-create reuses the directory safely: the
/// drop deletes the on-disk state, and the re-created collection (with
/// a different scheme) never replays the old WAL.
#[test]
fn collections_drop_then_recreate_reuses_directory() {
    let dir = temp_dir("recreate");
    let cfg = data_dir_cfg(&dir);
    let live = ServiceState::open(projector(64), &cfg).unwrap();
    match live.handle(Request::CreateCollection {
        name: "tmp".into(),
        scheme: Scheme::TwoBit,
        w: 0.75,
        bits: 0,
        k: 64,
        seed: 3,
        checkpoint_every: 0,
        kind: MatrixKind::Gaussian,
    }) {
        Response::CollectionCreated { .. } => {}
        other => panic!("unexpected {other:?}"),
    }
    let mut g = Pcg64::new(4, 4);
    for i in 0..20 {
        register(&live, Some("tmp"), &format!("old{i}"), vec_of(&mut g, 24));
    }
    assert!(dir.join("tmp").is_dir(), "durable collection has a dir");
    match live.handle(Request::DropCollection { name: "tmp".into() }) {
        Response::CollectionDropped { existed } => assert!(existed),
        other => panic!("unexpected {other:?}"),
    }
    assert!(
        !dir.join("tmp").exists(),
        "drop must delete the collection directory"
    );
    // Re-create under the same name with a different coding.
    match live.handle(Request::CreateCollection {
        name: "tmp".into(),
        scheme: Scheme::Uniform,
        w: 1.0,
        bits: 0,
        k: 64,
        seed: 9,
        checkpoint_every: 0,
        kind: MatrixKind::Gaussian,
    }) {
        Response::CollectionCreated { .. } => {}
        other => panic!("unexpected {other:?}"),
    }
    for i in 0..5 {
        register(&live, Some("tmp"), &format!("new{i}"), vec_of(&mut g, 24));
    }
    let tmp = live.registry.get("tmp").unwrap();
    assert_eq!(tmp.spec.bits(), 4);
    assert_eq!(tmp.store.len(), 5, "old rows must be gone");
    assert!(tmp.store.get("old0").is_none());

    // Restart from disk: the MANIFEST records the NEW spec, and the
    // directory holds only the new rows.
    let restarted = ServiceState::open(projector(64), &cfg).unwrap();
    let back = restarted.registry.get("tmp").unwrap();
    assert_eq!(back.spec, tmp.spec);
    assert_eq!(dump(&back.store), dump(&tmp.store));
    assert_eq!(back.store.len(), 5);
    assert!(back.store.get("old7").is_none());
    std::fs::remove_dir_all(&dir).ok();
}

fn spawn_server(cfg: ServerConfig, k: usize) -> String {
    let projector = projector(k);
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = serve(projector, cfg, Some(tx));
    });
    rx.recv()
        .expect("server thread exited before reporting its bound address")
        .to_string()
}

/// The namespaced client end-to-end over TCP: collection admin, scoped
/// register/estimate/knn/topk/remove, and the collections/connections
/// stats fields.
#[test]
fn collections_over_tcp_with_namespaced_client() {
    let addr = spawn_server(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        128,
    );
    let mut c = SketchClient::connect(&addr).unwrap();
    c.create_collection("web", Scheme::Uniform, 1.0, 64, 21, 0).unwrap();
    assert!(c.create_collection("web", Scheme::Uniform, 1.0, 64, 21, 0).is_err());

    let mut g = Pcg64::new(13, 13);
    let anchor = vec_of(&mut g, 32);
    c.register_in(Some("web"), "anchor", anchor.clone()).unwrap();
    let n = c
        .register_batch_in(
            Some("web"),
            vec!["p0".into(), "p1".into()],
            vec![vec_of(&mut g, 32), vec_of(&mut g, 32)],
        )
        .unwrap();
    assert_eq!(n, 2);
    c.register("legacy", vec_of(&mut g, 32)).unwrap();

    let (rho, _) = c.estimate_vec_in(Some("web"), "anchor", anchor.clone()).unwrap();
    assert!(rho > 0.999, "self-similarity in web: {rho}");
    let hits = c.knn_in(Some("web"), anchor.clone(), 3).unwrap();
    assert_eq!(hits[0].id, "anchor");
    assert_eq!(hits.len(), 3, "web has exactly 3 rows");
    let results = c.topk_in(Some("web"), vec![anchor.clone()], 3).unwrap();
    assert_eq!(results[0], hits);
    // The legacy namespace sees none of it.
    let legacy_hits = c.knn(anchor, 10).unwrap();
    assert_eq!(legacy_hits.len(), 1);
    assert_eq!(legacy_hits[0].id, "legacy");
    assert!(c.estimate_in(Some("web"), "anchor", "legacy").is_err());

    let infos = c.list_collections().unwrap();
    assert_eq!(infos.len(), 2);
    assert_eq!(infos[0].name, "default");
    assert_eq!(infos[1].name, "web");
    assert_eq!(infos[1].rows, 3);
    assert_eq!(infos[1].bits, 4);
    assert_eq!(infos[1].seed, 21);
    assert!(!infos[1].durable);

    let stats = c.stats().unwrap();
    assert_eq!(stats.collections, 2);
    assert_eq!(stats.connections, 1);
    assert_eq!(stats.registered, 4);

    assert!(c.remove_in(Some("web"), "p1").unwrap());
    assert!(!c.remove_in(Some("web"), "p1").unwrap());
    assert!(c.persist_in(Some("web")).is_err(), "in-memory collection");
    assert!(c.drop_collection("web").unwrap());
    assert!(!c.drop_collection("web").unwrap());
    assert!(c.knn_in(Some("web"), vec![1.0; 8], 1).is_err());
}

/// Per-collection checkpoint cadence: `checkpoint_every` on
/// `CreateCollection` overrides the global `--checkpoint-every`,
/// survives restart via the MANIFEST, and collections created without
/// it keep riding the global cadence.
#[test]
fn collections_per_collection_checkpoint_cadence() {
    let dir = temp_dir("cadence");
    let cfg = ServerConfig {
        data_dir: Some(dir.to_path_buf()),
        checkpoint_every: 1000, // global: far beyond this test's writes
        maintenance: MaintenanceConfig {
            tick: Duration::from_secs(60),
        },
        ..Default::default()
    };
    let live = ServiceState::open(projector(64), &cfg).unwrap();
    match live.handle(Request::CreateCollection {
        name: "fast".into(),
        scheme: Scheme::TwoBit,
        w: 0.75,
        bits: 0,
        k: 48,
        seed: 2,
        checkpoint_every: 5,
        kind: MatrixKind::Gaussian,
    }) {
        Response::CollectionCreated { .. } => {}
        other => panic!("unexpected {other:?}"),
    }
    let mut g = Pcg64::new(21, 0);
    for i in 0..4 {
        register(&live, Some("fast"), &format!("v{i}"), vec_of(&mut g, 24));
    }
    let fast = live.registry.get("fast").unwrap();
    assert_eq!(fast.options.checkpoint_every, 5);
    let d = fast.durability.as_ref().unwrap();
    assert!(!d.checkpoint_due(), "4 rows < cadence 5");
    for i in 4..6 {
        register(&live, Some("fast"), &format!("v{i}"), vec_of(&mut g, 24));
    }
    assert!(d.checkpoint_due(), "6 rows >= cadence 5");
    // The default collection rides the global cadence: 10 rows, not due.
    for i in 0..10 {
        register(&live, None, &format!("d{i}"), vec_of(&mut g, 24));
    }
    assert!(!live.default.durability.as_ref().unwrap().checkpoint_due());

    // Cadence survives restart via the MANIFEST.
    drop(live); // graceful shutdown checkpoints and resets the counters
    let back = ServiceState::open(projector(64), &cfg).unwrap();
    let fast = back.registry.get("fast").unwrap();
    assert_eq!(
        fast.options.checkpoint_every, 5,
        "cadence must be recorded in the MANIFEST"
    );
    let d = fast.durability.as_ref().unwrap();
    assert!(!d.checkpoint_due());
    for i in 0..5 {
        register(&back, Some("fast"), &format!("w{i}"), vec_of(&mut g, 24));
    }
    assert!(d.checkpoint_due());
    std::fs::remove_dir_all(&dir).ok();
}

/// `ApproxTopK` over TCP (namespaced) + the per-collection stats
/// breakdown: small stores answer byte-identically to exact `TopK`
/// (the fallback oracle), and `Stats` ships one breakdown entry per
/// collection, sorted by name, without touching the aggregates.
#[test]
fn collections_approx_and_stats_breakdown_over_tcp() {
    let addr = spawn_server(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        128,
    );
    let mut c = SketchClient::connect(&addr).unwrap();
    c.create_collection("web", Scheme::OneBit, 0.0, 96, 3, 7).unwrap();
    let mut g = Pcg64::new(5, 5);
    let ids: Vec<String> = (0..40).map(|i| format!("p{i:02}")).collect();
    let vectors: Vec<Vec<f32>> = (0..40).map(|_| vec_of(&mut g, 32)).collect();
    assert_eq!(c.register_batch_in(Some("web"), ids, vectors).unwrap(), 40);
    let q = vec_of(&mut g, 32);
    let exact = c.topk_in(Some("web"), vec![q.clone()], 5).unwrap();
    let approx = c
        .approx_topk_in(Some("web"), vec![q.clone()], 5, 3)
        .unwrap();
    assert_eq!(exact, approx, "small stores fall back to the exact oracle");
    assert_eq!(exact[0].len(), 5);
    // Unknown collections error cleanly on the approx path.
    assert!(c.approx_topk_in(Some("ghost"), vec![q], 5, 0).is_err());
    // The detailed breakdown names every collection with its live
    // gauges; the legacy Stats frame stays aggregates-only.
    let st = c.stats_detailed().unwrap();
    assert_eq!(st.collections, 2);
    let names: Vec<&str> = st.per_collection.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(names, ["default", "web"]);
    assert_eq!(st.per_collection[1].rows, 40);
    assert_eq!(st.per_collection[0].rows, 0);
    assert_eq!(st.per_collection[1].wal_bytes, 0, "in-memory collection");
    let legacy = c.stats().unwrap();
    assert_eq!(legacy.collections, 2);
    assert!(legacy.per_collection.is_empty());
}

/// `--max-conns` satellite: over-limit connections get one clean Error
/// frame and close; slots free up when clients disconnect; the
/// `connections` gauge tracks the live count.
#[test]
fn connection_cap_rejects_over_limit_with_clean_error() {
    let addr = spawn_server(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_conns: 2,
            ..Default::default()
        },
        64,
    );
    let mut c1 = SketchClient::connect(&addr).unwrap();
    c1.ping().unwrap();
    let mut c2 = SketchClient::connect(&addr).unwrap();
    c2.ping().unwrap();
    assert_eq!(c1.stats().unwrap().connections, 2);

    // The third connection is rejected with one clean Error frame,
    // pushed before any request is sent (read it without writing, so
    // the frame can never be lost to a TCP reset race).
    let c3 = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = std::io::BufReader::new(c3);
    let frame = crp::coordinator::protocol::read_frame(&mut reader)
        .expect("over-limit connection must get an Error frame");
    match Response::decode(&frame).unwrap() {
        Response::Error { message } => assert!(
            message.contains("connection limit"),
            "rejection must name the cause: {message}"
        ),
        other => panic!("unexpected {other:?}"),
    }

    // Freeing a slot lets a new client in (the server notices the
    // close asynchronously, so poll with a deadline).
    drop(c2);
    drop(reader);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let mut c4 = SketchClient::connect(&addr).unwrap();
        if c4.ping().is_ok() {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "server never freed a connection slot"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    c1.ping().unwrap();
}
