//! The reactor's zero-allocation pin: at steady state, a fixed-size
//! request costs the serving path no heap allocation at all — reads
//! land in the connection's grown buffer, decode borrows the frame,
//! the response encodes into retained write-buffer capacity, and the
//! counters are plain atomics.
//!
//! The test installs a counting global allocator and measures windows
//! of round trips against an in-process reactor server. Background
//! threads (maintenance wakes every 200 ms) allocate occasionally, so
//! the assertion is on the *minimum* delta across many short windows:
//! if the request path itself allocated, every window would be nonzero.
//!
//! This lives in its own test binary so concurrently running tests
//! can't allocate into the measurement windows. The `serve_` name keeps
//! it inside CI's `cargo test --release -q serve` step, and the sharded
//! leg (`CRP_SERVE_MODE=reactor-multi`) re-runs it standalone against
//! 4 loops + 2 workers.
#![cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Counts every allocation and reallocation; frees are not counted
/// (a path that frees must have allocated somewhere already).
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

#[test]
fn serve_reactor_steady_state_allocates_nothing_per_request() {
    use crp::coordinator::protocol::{self, Request, Response};
    use crp::coordinator::server::{serve, ServerConfig, ServerMode};
    use crp::projection::{ProjectionConfig, Projector};

    let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
        k: 64,
        seed: 7,
        ..Default::default()
    }));
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        server_mode: ServerMode::Reactor,
        ..Default::default()
    };
    // CI's sharded leg re-runs the pin against the multi-loop + worker
    // layout: the loop that owns this connection must stay just as
    // allocation-free (Ping never offloads, so workers sit idle).
    if std::env::var("CRP_SERVE_MODE").as_deref() == Ok("reactor-multi") {
        cfg.reactor_threads = 4;
        cfg.reactor_workers = 2;
    }
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = serve(projector, cfg, Some(tx));
    });
    let addr = rx.recv().unwrap().to_string();

    // Pre-encoded request frame and a reused response buffer: the
    // client side of the loop is allocation-free too, so any window
    // delta is the server's (same process, same allocator).
    let payload = Request::Ping.encode();
    let mut frame = Vec::with_capacity(4 + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut resp = Vec::with_capacity(256);

    // Warm up: grow the connection's read/write buffers and the
    // client's response buffer to their steady-state sizes.
    for _ in 0..100 {
        stream.write_all(&frame).unwrap();
        protocol::read_frame_into(&mut stream, &mut resp).unwrap();
    }
    assert_eq!(Response::decode(&resp).unwrap(), Response::Pong);

    let mut min_delta = u64::MAX;
    let mut deltas = Vec::with_capacity(40);
    for _ in 0..40 {
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..25 {
            stream.write_all(&frame).unwrap();
            protocol::read_frame_into(&mut stream, &mut resp).unwrap();
        }
        let delta = ALLOCS.load(Ordering::Relaxed) - before;
        deltas.push(delta);
        min_delta = min_delta.min(delta);
    }
    assert_eq!(
        min_delta, 0,
        "every 25-request window allocated — the reactor request path \
         is not allocation-free: {deltas:?}"
    );
}
