//! Cross-module integration tests: the full pipelines the paper's
//! experiments exercise, composed exactly as the CLI/examples compose
//! them (no PJRT requirement — see `pjrt_roundtrip.rs` for that axis).

use crp::coding::{CodingParams, Scheme};
use crp::data::synth::{SynthKind, SynthSpec};
use crp::estimator::CollisionEstimator;
use crp::projection::{ProjectionConfig, Projector};
use crp::svm::sweep::{project_dataset, run_coded_svm, SvmTask};
use crp::theory::SchemeKind;

/// End-to-end estimation through real projections (not the bivariate
/// shortcut): data pair → R → codes → collision inversion, against the
/// true ρ, for every scheme. This is the paper's core claim in one test.
#[test]
fn projection_coding_estimation_pipeline() {
    let k = 8192;
    let proj = Projector::new_cpu(ProjectionConfig {
        k,
        seed: 3,
        ..Default::default()
    });
    for &rho in &[0.2, 0.56, 0.9] {
        let (u, v) = crp::data::pairs::unit_pair_with_rho(300, rho, 7);
        let xu = proj.project_dense(&u);
        let xv = proj.project_dense(&v);
        for scheme in SchemeKind::ALL {
            let w = if scheme == SchemeKind::OneBit { 0.0 } else { 0.75 };
            let params = CodingParams::new(scheme, w);
            let est = CollisionEstimator::new(params.clone());
            let e = est.estimate_with_error(&params.encode(&xu), &params.encode(&xv));
            assert!(
                (e.rho - rho).abs() < 4.0 * e.std_err + 0.02,
                "{scheme:?} rho={rho}: est {} ± {}",
                e.rho,
                e.std_err
            );
        }
    }
}

/// Theory ↔ empirics: with fixed w, the error ordering across schemes
/// must match the variance factors V at that (ρ, w) — the measurable
/// content of Figures 4/7/10.
#[test]
fn variance_ordering_matches_theory_at_fixed_w() {
    let rho = 0.5;
    let w = 5.0; // the regime where V_wq blows up (Figure 4): ratio ≈ 3.2
    let k = 2048;
    let reps = 150;
    let mse = |scheme: Scheme| -> f64 {
        let params = CodingParams::new(scheme, w);
        let est = CollisionEstimator::new(params.clone());
        let mut acc = 0.0;
        for r in 0..reps {
            let (x, y) = crp::data::pairs::bivariate_normal_batch(k, rho, 40_000 + r);
            let e = est.estimate(&params.encode(&x), &params.encode(&y));
            acc += (e - rho) * (e - rho);
        }
        acc / reps as f64
    };
    let mse_hw = mse(Scheme::Uniform);
    let mse_hwq = mse(Scheme::WindowOffset);
    // Theory: V_w(0.5, 2.5) << V_wq(0.5, 2.5).
    let vw = SchemeKind::Uniform.variance_factor(rho, w);
    let vwq = SchemeKind::WindowOffset.variance_factor(rho, w);
    assert!(vwq / vw > 2.5, "theory gap missing: {vw} vs {vwq}");
    assert!(
        mse_hwq > mse_hw * 1.3,
        "empirical ordering violated: h_w {mse_hw:.2e} vs h_wq {mse_hwq:.2e}"
    );
}

/// The Section-6 SVM experiment at smoke scale, on all three corpora —
/// coded features must carry the class signal on every dataset shape.
#[test]
fn svm_pipeline_all_three_datasets() {
    for kind in [SynthKind::UrlLike, SynthKind::FarmLike, SynthKind::ArceneLike] {
        let spec = SynthSpec::small(kind);
        let (train, test) = spec.generate();
        let k = 128;
        let proj = Projector::new_cpu(ProjectionConfig {
            k,
            seed: 5,
            ..Default::default()
        });
        let ptr = project_dataset(&train, &proj);
        let pte = project_dataset(&test, &proj);
        let r = run_coded_svm(
            &ptr,
            &train.y,
            &pte,
            &test.y,
            k,
            &SvmTask::Coded(CodingParams::new(Scheme::TwoBit, 0.75)),
            1.0,
        );
        assert!(
            r.test_acc > 0.6,
            "{kind:?}: 2-bit coded SVM only {:.3}",
            r.test_acc
        );
    }
}

/// libsvm round-trip through the real pipeline: write a synthetic
/// dataset, re-read it, and verify the projections agree.
#[test]
fn libsvm_roundtrip_preserves_projections() {
    let (train, _) = SynthSpec::small(SynthKind::FarmLike).generate();
    let path = std::env::temp_dir().join(format!("crp_it_{}.libsvm", std::process::id()));
    crp::data::libsvm::write_libsvm(&train, &path).unwrap();
    let back = crp::data::libsvm::read_libsvm(&path, train.x.cols).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back.len(), train.len());
    let proj = Projector::new_cpu(ProjectionConfig {
        k: 32,
        seed: 1,
        ..Default::default()
    });
    for r in (0..train.len()).step_by(17) {
        let (i1, v1) = train.x.row(r);
        let (i2, v2) = back.x.row(r);
        let a = proj.project_sparse(i1, v1);
        let b = proj.project_sparse(i2, v2);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }
}

/// Sketch-service consistency: similarity estimated over the wire equals
/// similarity estimated locally from the same projector + coding.
#[test]
fn service_estimates_match_local_pipeline() {
    use crp::coordinator::server::{ServerConfig, ServiceState};
    use crp::coordinator::protocol::{Request, Response};
    use std::sync::Arc;

    let cfg = ServerConfig::default();
    let proj_cfg = ProjectionConfig {
        k: 1024,
        seed: 0,
        ..Default::default()
    };
    let state = ServiceState::new(Arc::new(Projector::new_cpu(proj_cfg.clone())), &cfg);
    let (u, v) = crp::data::pairs::unit_pair_with_rho(200, 0.7, 9);
    state.handle(Request::Register {
        id: "u".into(),
        vector: u.clone(),
    });
    state.handle(Request::Register {
        id: "v".into(),
        vector: v.clone(),
    });
    let remote = match state.handle(Request::Estimate {
        a: "u".into(),
        b: "v".into(),
    }) {
        Response::Estimate { rho, .. } => rho,
        other => panic!("unexpected {other:?}"),
    };
    // Local replica of the same computation.
    let proj = Projector::new_cpu(proj_cfg);
    let params = cfg.coding.clone();
    let est = CollisionEstimator::new(params.clone());
    let local = est.estimate(
        &params.encode(&proj.project_dense(&u)),
        &params.encode(&proj.project_dense(&v)),
    );
    assert!(
        (remote - local).abs() < 1e-9,
        "remote {remote} vs local {local}"
    );
}

/// Figure machinery smoke: every figure renders and writes CSV.
#[test]
fn all_figures_generate_and_write() {
    let dir = std::env::temp_dir().join(format!("crp_figs_{}", std::process::id()));
    for fig in crp::figures::ALL_FIGURES {
        let scale = if fig >= 11 { 0.03 } else { 1.0 };
        let tables = crp::figures::run_figure(fig, scale)
            .unwrap_or_else(|e| panic!("figure {fig}: {e}"));
        assert!(!tables.is_empty());
        for t in tables {
            assert!(!t.rows.is_empty(), "figure {fig} table {} empty", t.name);
            t.write_csv(&dir).unwrap();
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
