//! Integration: the real AOT artifacts (built by `make artifacts`) load,
//! compile, and execute through the PJRT runtime, and their numerics
//! match the pure-Rust oracle — the full Python→HLO→Rust bridge.
//!
//! These tests are skipped (with a message) when `artifacts/` has not
//! been built, so `cargo test` stays runnable before `make artifacts`.

use crp::coding::{CodingParams, Scheme};
use crp::projection::{ProjectionConfig, Projector};
use crp::runtime::{ArtifactId, ArtifactRegistry, PjrtRuntime};
use std::sync::Arc;

fn runtime_or_skip() -> Option<Arc<PjrtRuntime>> {
    let reg = ArtifactRegistry::default_location();
    if !reg.exists(&ArtifactId::proj_acc(64, 1024, 256)) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(PjrtRuntime::cpu(reg).expect("PJRT runtime")))
}

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut g = crp::mathx::Pcg64::new(seed, 0);
    (0..n).map(|_| (g.next_f64() as f32 - 0.5) * 2.0).collect()
}

#[test]
fn all_artifacts_compile() {
    let Some(rt) = runtime_or_skip() else { return };
    for id in rt.registry().list() {
        rt.executable(&id)
            .unwrap_or_else(|e| panic!("artifact {} failed to compile: {e}", id.0));
    }
}

#[test]
fn proj_acc_artifact_matches_rust_gemm() {
    let Some(rt) = runtime_or_skip() else { return };
    let (b, d, k) = (64usize, 1024usize, 256usize);
    let u = randv(b * d, 1);
    let r = randv(d * k, 2);
    let acc = randv(b * k, 3);
    let id = ArtifactId::proj_acc(b, d, k);
    let out = rt
        .execute(
            &id,
            &[
                PjrtRuntime::literal_f32(&u, &[b as i64, d as i64]).unwrap(),
                PjrtRuntime::literal_f32(&r, &[d as i64, k as i64]).unwrap(),
                PjrtRuntime::literal_f32(&acc, &[b as i64, k as i64]).unwrap(),
            ],
        )
        .unwrap();
    let got = PjrtRuntime::to_vec_f32(&out[0]).unwrap();
    let mut want = acc.clone();
    crp::projection::gemm::gemm_acc(&u, &r, &mut want, b, d, k);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() < 1e-2 * (1.0 + w.abs()),
            "mismatch at {i}: {g} vs {w}"
        );
    }
}

#[test]
fn quantize_artifact_matches_rust_encoders() {
    let Some(rt) = runtime_or_skip() else { return };
    let (b, k) = (64usize, 256usize);
    let x = randv(b * k, 5);
    let w = 0.75f32;
    let params_hw = CodingParams::new(Scheme::Uniform, w as f64);
    let params_hwq = CodingParams::new(Scheme::WindowOffset, w as f64);
    let params_h2 = CodingParams::new(Scheme::TwoBit, w as f64);
    let params_h1 = CodingParams::new(Scheme::OneBit, 0.0);
    let offsets: Vec<f64> = params_hwq.offsets(k);
    let offs_f32: Vec<f32> = offsets.iter().map(|&q| q as f32).collect();
    let id = ArtifactId::quantize_all(b, k);
    let out = rt
        .execute(
            &id,
            &[
                PjrtRuntime::literal_f32(&x, &[b as i64, k as i64]).unwrap(),
                PjrtRuntime::literal_scalar_f32(w),
                PjrtRuntime::literal_f32(&offs_f32, &[k as i64]).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 4);
    let hw = PjrtRuntime::to_vec_i32(&out[0]).unwrap();
    let hwq = PjrtRuntime::to_vec_i32(&out[1]).unwrap();
    let hw2 = PjrtRuntime::to_vec_i32(&out[2]).unwrap();
    let h1 = PjrtRuntime::to_vec_i32(&out[3]).unwrap();
    let mut mismatches = 0usize;
    for row in 0..b {
        let xs = &x[row * k..(row + 1) * k];
        let want_hw = params_hw.encode(xs);
        let want_h2 = params_h2.encode(xs);
        let want_h1 = params_h1.encode(xs);
        let mut want_hwq = vec![0u16; k];
        params_hwq.encode_into(xs, Some(&offsets), &mut want_hwq);
        for j in 0..k {
            // f32 (kernel) vs f64 (Rust) floor can differ exactly on a
            // bin boundary; count and bound rather than require equality.
            mismatches += usize::from(hw[row * k + j] != want_hw[j] as i32);
            mismatches += usize::from(hwq[row * k + j] != want_hwq[j] as i32);
            mismatches += usize::from(hw2[row * k + j] != want_h2[j] as i32);
            mismatches += usize::from(h1[row * k + j] != want_h1[j] as i32);
        }
    }
    let frac = mismatches as f64 / (4 * b * k) as f64;
    assert!(frac < 1e-3, "code mismatch fraction {frac}");
}

#[test]
fn collision_artifact_matches_rust_counts() {
    let Some(rt) = runtime_or_skip() else { return };
    let (b, k) = (64usize, 256usize);
    let mut g = crp::mathx::Pcg64::new(77, 0);
    let a: Vec<i32> = (0..b * k).map(|_| g.next_below(4) as i32).collect();
    let c: Vec<i32> = (0..b * k).map(|_| g.next_below(4) as i32).collect();
    let id = ArtifactId::collision(b, k);
    let out = rt
        .execute(
            &id,
            &[
                PjrtRuntime::literal_i32(&a, &[b as i64, k as i64]).unwrap(),
                PjrtRuntime::literal_i32(&c, &[b as i64, k as i64]).unwrap(),
            ],
        )
        .unwrap();
    let counts = PjrtRuntime::to_vec_i32(&out[0]).unwrap();
    assert_eq!(counts.len(), b);
    for row in 0..b {
        let want = (0..k)
            .filter(|&j| a[row * k + j] == c[row * k + j])
            .count() as i32;
        assert_eq!(counts[row], want, "row {row}");
    }
}

#[test]
fn proj_code_artifact_matches_fused_pipeline() {
    let Some(rt) = runtime_or_skip() else { return };
    let (b, d, k) = (64usize, 1024usize, 256usize);
    let u = randv(b * d, 9);
    let r = randv(d * k, 10);
    let w = 0.75f32;
    let id = ArtifactId::proj_code(b, d, k);
    let out = rt
        .execute(
            &id,
            &[
                PjrtRuntime::literal_f32(&u, &[b as i64, d as i64]).unwrap(),
                PjrtRuntime::literal_f32(&r, &[d as i64, k as i64]).unwrap(),
                PjrtRuntime::literal_scalar_f32(w),
            ],
        )
        .unwrap();
    let codes = PjrtRuntime::to_vec_i32(&out[0]).unwrap();
    // Oracle: Rust GEMM then Rust 2-bit encoder.
    let mut x = vec![0.0f32; b * k];
    crp::projection::gemm::gemm_acc(&u, &r, &mut x, b, d, k);
    let params = CodingParams::new(Scheme::TwoBit, w as f64);
    let mut mismatches = 0usize;
    for row in 0..b {
        let want = params.encode(&x[row * k..(row + 1) * k]);
        for j in 0..k {
            mismatches += usize::from(codes[row * k + j] != want[j] as i32);
        }
    }
    let frac = mismatches as f64 / (b * k) as f64;
    assert!(frac < 2e-3, "fused code mismatch fraction {frac}");
}

#[test]
fn pjrt_projector_matches_pure_backend() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ProjectionConfig {
        k: 256,
        seed: 4,
        d_tile: 1024,
        b_tile: 64,
        max_cached_tiles: 4,
    };
    let pure = Projector::new_cpu(cfg.clone());
    let pjrt = Projector::new_pjrt(cfg, rt);
    assert!(pjrt.pjrt_active(), "PJRT path should engage");
    let (bsz, d) = (10usize, 2500usize); // non-multiples: exercises padding
    let u = randv(bsz * d, 11);
    let a = pure.project_batch(&u, bsz, d);
    let b = pjrt.project_batch(&u, bsz, d);
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(
            (x - y).abs() < 1e-2 * (1.0 + x.abs()),
            "mismatch at {i}: {x} vs {y}"
        );
    }
}

#[test]
fn serving_stack_over_pjrt_end_to_end() {
    let Some(rt) = runtime_or_skip() else { return };
    use crp::coordinator::server::{ServerConfig, ServiceState};
    use crp::coordinator::protocol::{Request, Response};
    let projector = Arc::new(Projector::new_pjrt(
        ProjectionConfig {
            k: 256,
            seed: 0,
            d_tile: 1024,
            b_tile: 64,
            max_cached_tiles: 4,
        },
        rt,
    ));
    assert!(projector.pjrt_active());
    let state = ServiceState::new(projector, &ServerConfig::default());
    let (u, v) = crp::data::pairs::unit_pair_with_rho(128, 0.9, 2);
    state.handle(Request::Register {
        id: "u".into(),
        vector: u,
    });
    state.handle(Request::Register {
        id: "v".into(),
        vector: v,
    });
    match state.handle(Request::Estimate {
        a: "u".into(),
        b: "v".into(),
    }) {
        Response::Estimate { rho, std_err, .. } => {
            assert!(
                (rho - 0.9).abs() < 4.0 * std_err + 0.08,
                "rho {rho} err {std_err}"
            );
        }
        other => panic!("unexpected {other:?}"),
    }
}
