//! Crash-recovery tests for the durability layer: WAL truncation
//! tolerance (randomized), snapshot + WAL ≡ live store equivalence
//! (randomized, including removes and tombstone compaction), the
//! server-level `kill -9` equivalence pin, bulk cold restore, and
//! put-completes-during-checkpoint (the snapshot-under-load stall fix).
//!
//! Run standalone with `cargo test --release -q recovery` (CI does).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crp::coding::{pack_codes, PackedCodes};
use crp::coordinator::durability::{self, snapshot, wal, Durability, DurabilityConfig, FsyncPolicy};
use crp::coordinator::maintenance::MaintenanceConfig;
use crp::coordinator::protocol::{Request, Response};
use crp::coordinator::server::{ServerConfig, ServiceState};
use crp::coordinator::store::SketchStore;
use crp::mathx::Pcg64;
use crp::projection::{ProjectionConfig, Projector};
use crp::scan::{ArenaImage, EpochConfig};

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("crp_recovery_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn rand_sketch(g: &mut Pcg64, k: usize) -> PackedCodes {
    let codes: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
    pack_codes(&codes, 2)
}

/// Sorted `(id, raw words)` dump — the byte-for-byte comparison basis.
fn dump(store: &SketchStore) -> Vec<(String, Vec<u64>)> {
    let mut out = Vec::new();
    store.for_each(|id, codes| out.push((id.to_string(), codes.words().to_vec())));
    out.sort();
    out
}

#[derive(Clone)]
enum Op {
    Put(String, PackedCodes),
    PutRows(Vec<String>, Vec<u64>),
    Remove(String),
}

#[test]
fn recovery_wal_truncation_replays_clean_prefix() {
    let (k, bits) = (32usize, 2u32);
    const HEADER: u64 = 16; // magic + k + bits
    for case in 0..6u64 {
        let mut g = Pcg64::new(0x7AB1E ^ case, case);
        let dir = temp_dir(&format!("trunc{case}"));
        let wal_handle = wal::Wal::create(&dir, k, bits).unwrap();
        let stride = wal_handle.stride();
        let mut ops: Vec<Op> = Vec::new();
        let mut ends: Vec<u64> = Vec::new(); // file offset after each record
        for step in 0..30 {
            let id = format!("id{:02}", g.next_below(8));
            match g.next_below(5) {
                0 => {
                    wal_handle.append_remove(&id, || ()).unwrap();
                    ops.push(Op::Remove(id));
                }
                1 => {
                    let n = 1 + g.next_below(4) as usize;
                    let ids: Vec<String> =
                        (0..n).map(|j| format!("id{:02}", (step + j) % 11)).collect();
                    let mut words = Vec::with_capacity(n * stride);
                    for _ in 0..n {
                        words.extend_from_slice(rand_sketch(&mut g, k).words());
                    }
                    wal_handle.append_put_rows(&ids, &words, || ()).unwrap();
                    ops.push(Op::PutRows(ids, words));
                }
                _ => {
                    let codes = rand_sketch(&mut g, k);
                    wal_handle.append_put(&id, codes.words(), || ()).unwrap();
                    ops.push(Op::Put(id, codes));
                }
            }
            ends.push(HEADER + wal_handle.bytes());
        }
        drop(wal_handle);
        let (_, seg_path) = wal::segments(&dir).unwrap().pop().unwrap();
        let full = std::fs::read(&seg_path).unwrap();
        assert_eq!(full.len() as u64, *ends.last().unwrap(), "offset bookkeeping");

        let mut cuts: Vec<u64> = vec![0, 7, 15, HEADER, full.len() as u64];
        for _ in 0..12 {
            cuts.push(g.next_below(full.len() as u64 + 1));
        }
        for cut in cuts {
            std::fs::write(&seg_path, &full[..cut as usize]).unwrap();
            let store = SketchStore::with_arena(k, bits);
            // Arbitrary truncation must never be an error...
            let stats = wal::replay_into(&store, &dir).unwrap();
            // ...and must apply exactly the records fully below the cut.
            let applied = ends.iter().filter(|&&e| e <= cut).count();
            assert_eq!(stats.records as usize, applied, "cut {cut}");
            let clean = cut == HEADER || cut == full.len() as u64 || ends.contains(&cut);
            assert_eq!(stats.torn, !clean, "cut {cut}");
            let mut model: std::collections::HashMap<String, PackedCodes> =
                std::collections::HashMap::new();
            for op in &ops[..applied] {
                match op {
                    Op::Put(id, codes) => {
                        model.insert(id.clone(), codes.clone());
                    }
                    Op::PutRows(ids, words) => {
                        for (i, id) in ids.iter().enumerate() {
                            model.insert(
                                id.clone(),
                                PackedCodes::from_words(
                                    bits,
                                    k,
                                    words[i * stride..(i + 1) * stride].to_vec(),
                                ),
                            );
                        }
                    }
                    Op::Remove(id) => {
                        model.remove(id);
                    }
                }
            }
            assert_eq!(store.len(), model.len(), "cut {cut}");
            for (id, want) in &model {
                assert_eq!(store.get(id).as_ref(), Some(want), "cut {cut}: {id}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn recovery_snapshot_plus_wal_equals_live_store() {
    let k = 48usize;
    for case in 0..4u64 {
        let mut g = Pcg64::new(0x5EED ^ case, case);
        let dir = temp_dir(&format!("equiv{case}"));
        let cfg = DurabilityConfig {
            snapshot: dir.join("snapshot.bin"),
            wal_dir: dir.join("wal"),
            checkpoint_every: 0,
            fsync: FsyncPolicy::Os,
        };
        // Tiny thresholds so drains and tombstone compaction fire
        // mid-sequence (checkpoints drain too).
        let live = SketchStore::with_arena_config(
            k,
            2,
            EpochConfig {
                drain_threshold: 16,
                compact_ratio: 0.3,
                compact_min: 4,
            },
        );
        let (d, open_stats) = Durability::open(cfg.clone(), &live).unwrap();
        assert_eq!(open_stats.live, 0);
        let universe = 32u64;
        let mut checkpoints = 0;
        for step in 0..250 {
            let id = format!("id{:02}", g.next_below(universe));
            match g.next_below(10) {
                0 | 1 => {
                    d.log_remove(&id, || live.remove(&id)).unwrap();
                }
                2 if step > 20 => {
                    let (rows, _) = d.checkpoint(&live).unwrap();
                    assert_eq!(rows, live.len() as u64, "checkpoint covers the live set");
                    checkpoints += 1;
                }
                3 => {
                    let n = 1 + g.next_below(6) as usize;
                    let stride = live.arena().unwrap().stride();
                    let ids: Vec<String> = (0..n)
                        .map(|j| format!("id{:02}", (g.next_below(universe) + j as u64) % universe))
                        .collect();
                    let mut words = Vec::with_capacity(n * stride);
                    for _ in 0..n {
                        words.extend_from_slice(rand_sketch(&mut g, k).words());
                    }
                    d.log_put_rows(&ids, &words, || live.put_rows(&ids, &words))
                        .unwrap();
                }
                _ => {
                    let codes = rand_sketch(&mut g, k);
                    d.log_put(&id, &codes, || live.put(id.clone(), codes.clone()))
                        .unwrap();
                }
            }
        }
        assert!(checkpoints >= 1, "case {case}: no checkpoint exercised");

        let (back, rk, rbits, stats) = durability::recover(&cfg.snapshot, &cfg.wal_dir).unwrap();
        assert_eq!((rk, rbits), (k, 2), "case {case}");
        assert!(!stats.wal_torn, "case {case}: clean shutdown has no tear");
        assert_eq!(stats.live, live.len() as u64, "case {case}");
        // Byte-for-byte: identical id → packed-words maps...
        assert_eq!(dump(&back), dump(&live), "case {case}");
        // ...and identical rankings through the scan engine.
        for q in 0..3 {
            let query = rand_sketch(&mut g, k);
            let strip = |hits: Vec<crp::scan::ScanHit>| -> Vec<(String, usize)> {
                hits.into_iter().map(|h| (h.id, h.collisions)).collect()
            };
            assert_eq!(
                strip(back.arena().unwrap().scan_topk(&query, 10, 1)),
                strip(live.arena().unwrap().scan_topk(&query, 10, 1)),
                "case {case} query {q}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

fn projector(k: usize) -> Arc<Projector> {
    Arc::new(Projector::new_cpu(ProjectionConfig {
        k,
        seed: 7,
        ..Default::default()
    }))
}

fn durable_cfg(dir: &Path) -> ServerConfig {
    ServerConfig {
        durability: Some(DurabilityConfig {
            snapshot: dir.join("snapshot.bin"),
            wal_dir: dir.join("wal"),
            checkpoint_every: 0, // explicit Persist only — keeps the test deterministic
            fsync: FsyncPolicy::Os,
        }),
        maintenance: MaintenanceConfig {
            tick: Duration::from_secs(60),
        },
        ..Default::default()
    }
}

/// The acceptance pin: a server seeded with N registers + M removes,
/// checkpointed at an arbitrary point and "killed" (state rebuilt from
/// disk with no graceful shutdown), answers Knn/TopK/Estimate
/// byte-identically to the never-restarted server.
#[test]
fn recovery_kill9_server_equivalence() {
    let dir = temp_dir("kill9");
    let cfg = durable_cfg(&dir);
    let live = ServiceState::open(projector(256), &cfg).unwrap();
    let mut g = Pcg64::new(99, 0);
    let vec_of = |seed: &mut Pcg64| -> Vec<f32> {
        (0..40).map(|_| seed.next_f64() as f32 - 0.5).collect()
    };
    // N registers: singles + one bulk batch.
    for i in 0..60 {
        let r = live.handle(Request::Register {
            id: format!("v{i:02}"),
            vector: vec_of(&mut g),
        });
        assert!(matches!(r, Response::Registered { .. }), "{r:?}");
    }
    let bulk_ids: Vec<String> = (0..30).map(|i| format!("b{i:02}")).collect();
    let bulk_vecs: Vec<Vec<f32>> = (0..30).map(|_| vec_of(&mut g)).collect();
    match live.handle(Request::RegisterBatch {
        ids: bulk_ids.clone(),
        vectors: bulk_vecs,
    }) {
        Response::RegisteredBatch { count } => assert_eq!(count, 30),
        other => panic!("unexpected {other:?}"),
    }
    // M removes.
    for i in (0..40).step_by(2) {
        match live.handle(Request::Remove {
            id: format!("v{i:02}"),
        }) {
            Response::Removed { existed } => assert!(existed),
            other => panic!("unexpected {other:?}"),
        }
    }
    // Checkpoint at an arbitrary point...
    match live.handle(Request::Persist) {
        Response::Persisted { rows, .. } => assert_eq!(rows, 70),
        other => panic!("unexpected {other:?}"),
    }
    // ...then keep mutating: overwrites, fresh rows, more removes.
    for i in 60..75 {
        live.handle(Request::Register {
            id: format!("v{i:02}"),
            vector: vec_of(&mut g),
        });
    }
    live.handle(Request::Register {
        id: "v01".into(),
        vector: vec_of(&mut g),
    });
    for id in ["b03", "b07"] {
        live.handle(Request::Remove { id: id.into() });
    }

    // kill -9: rebuild purely from disk while the first instance is
    // still alive — nothing graceful (no shutdown flush) has run, so
    // this is exactly the state a crashed process leaves behind.
    let restarted = ServiceState::open(projector(256), &cfg).unwrap();
    assert_eq!(restarted.store.len(), live.store.len());
    assert_eq!(dump(&restarted.store), dump(&live.store));
    // Byte-identical responses on every read path.
    for q in 0..5 {
        let v = vec_of(&mut g);
        assert_eq!(
            live.handle(Request::Knn {
                vector: v.clone(),
                n: 10
            }),
            restarted.handle(Request::Knn { vector: v, n: 10 }),
            "knn query {q}"
        );
    }
    let batch: Vec<Vec<f32>> = (0..4).map(|_| vec_of(&mut g)).collect();
    assert_eq!(
        live.handle(Request::TopK {
            vectors: batch.clone(),
            n: 5
        }),
        restarted.handle(Request::TopK {
            vectors: batch,
            n: 5
        })
    );
    for (a, b) in [("v01", "v03"), ("b00", "b29"), ("v00", "v03"), ("b03", "b00")] {
        assert_eq!(
            live.handle(Request::Estimate {
                a: a.into(),
                b: b.into()
            }),
            restarted.handle(Request::Estimate {
                a: a.into(),
                b: b.into()
            }),
            "{a}/{b}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Cold restore goes through `put_rows` bulk ingest: restoring 1e5
/// sketches takes zero per-sketch epoch-buffer trips.
#[test]
fn recovery_cold_restore_of_1e5_is_bulk_only() {
    let (k, bits, n) = (64usize, 1u32, 100_000usize);
    let mut g = Pcg64::new(4, 4);
    let mut img = ArenaImage::empty(k, bits);
    assert_eq!(img.stride, 1);
    for i in 0..n {
        img.ids.push(Some(format!("{i:06}")));
        img.words.push(g.next_u64());
    }
    let dir = temp_dir("cold");
    let path = dir.join("snapshot.bin");
    assert_eq!(snapshot::save(&path, &img).unwrap(), n as u64);

    let store = SketchStore::with_arena(k, bits);
    let restored = snapshot::restore_into(&store, &snapshot::load(&path).unwrap()).unwrap();
    assert_eq!(restored, n as u64);
    assert_eq!(store.len(), n);
    let arena = store.arena().unwrap();
    assert_eq!(
        arena.single_puts(),
        0,
        "cold restore must never take the per-sketch put path"
    );
    for i in [0usize, 1, 4096, 99_999] {
        let id = format!("{i:06}");
        assert_eq!(store.get(&id).unwrap().words(), &img.words[i..i + 1], "{id}");
        assert_eq!(arena.get(&id).unwrap().words(), &img.words[i..i + 1]);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A writer that parks on its first byte until released — freezing the
/// snapshot mid-"disk write" deterministically.
struct GatedWriter {
    started: std::sync::mpsc::Sender<()>,
    gate: std::sync::mpsc::Receiver<()>,
    blocked_once: bool,
    out: Vec<u8>,
}

impl std::io::Write for GatedWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if !self.blocked_once {
            self.blocked_once = true;
            let _ = self.started.send(());
            let _ = self.gate.recv();
        }
        self.out.extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// The snapshot-under-load fix: serialization works from an owned
/// sealed image, so a put completes while the checkpoint is frozen in
/// the middle of its disk write (the seed `save_store` held shard read
/// locks across file I/O here and writes stalled for the whole dump).
#[test]
fn recovery_put_completes_during_checkpoint_disk_write() {
    use std::sync::mpsc;

    let store = Arc::new(SketchStore::with_arena(64, 2));
    let mut g = Pcg64::new(8, 8);
    for i in 0..2000 {
        store.put(format!("seed{i:04}"), rand_sketch(&mut g, 64));
    }
    store.arena().unwrap().drain();
    let image = store.arena().unwrap().sealed_image();

    let (started_tx, started_rx) = mpsc::channel();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let writer_image = image.clone();
    let serializer = std::thread::spawn(move || {
        let mut w = GatedWriter {
            started: started_tx,
            gate: gate_rx,
            blocked_once: false,
            out: Vec::new(),
        };
        snapshot::write_image(&mut w, &writer_image).unwrap();
        w.out
    });
    started_rx.recv().unwrap(); // snapshot is now mid-write, frozen

    // Puts, removes, and scans must all complete while it is frozen.
    let (done_tx, done_rx) = mpsc::channel();
    let prober = {
        let store = store.clone();
        let codes = rand_sketch(&mut g, 64);
        std::thread::spawn(move || {
            store.put("during-checkpoint".into(), codes);
            assert!(store.remove("seed0000"));
            let q = store.get("seed0001").unwrap();
            let hits = store.arena().unwrap().scan_topk(&q, 5, 1);
            assert_eq!(hits.first().map(|h| h.id.as_str()), Some("seed0001"));
            done_tx.send(()).unwrap();
        })
    };
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("writes stalled behind an in-flight checkpoint disk write");
    gate_tx.send(()).unwrap();
    let bytes = serializer.join().unwrap();
    prober.join().unwrap();

    // The frozen writer still produced a byte-perfect snapshot of the
    // pre-checkpoint state.
    let dir = temp_dir("gated");
    let path = dir.join("snapshot.bin");
    std::fs::write(&path, &bytes).unwrap();
    assert_eq!(snapshot::load(&path).unwrap(), image);
    std::fs::remove_dir_all(&dir).ok();

    // End-to-end: a real checkpoint with concurrent writers completes
    // and recovers to the merged state (no lock is held across I/O).
    let dir = temp_dir("ckpt_load");
    let cfg = DurabilityConfig {
        snapshot: dir.join("snapshot.bin"),
        wal_dir: dir.join("wal"),
        checkpoint_every: 0,
        fsync: FsyncPolicy::Os,
    };
    let (d, _) = Durability::open(cfg.clone(), &store).unwrap();
    let d = Arc::new(d);
    let writer = {
        let (store, d) = (store.clone(), d.clone());
        let mut g = Pcg64::new(9, 9);
        std::thread::spawn(move || {
            for i in 0..200 {
                let codes = rand_sketch(&mut g, 64);
                let id = format!("live{i:03}");
                d.log_put(&id, &codes, || store.put(id.clone(), codes.clone()))
                    .unwrap();
            }
        })
    };
    for _ in 0..5 {
        d.checkpoint(&store).unwrap();
    }
    writer.join().unwrap();
    d.checkpoint(&store).unwrap();
    let (back, _, _, _) = durability::recover(&cfg.snapshot, &cfg.wal_dir).unwrap();
    assert_eq!(dump(&back), dump(&store));
    std::fs::remove_dir_all(&dir).ok();
}

/// The banded ANN index is derived state: it is never written to disk
/// (no new on-disk format); recovery rebuilds it from the restored
/// arena at the first drain. After `kill -9`, once both sides are
/// fully drained, `ApproxTopK` answers byte-identically to the
/// never-restarted server at every probe budget, and every approx hit
/// carries the exact score the full scan reports.
#[test]
fn recovery_kill9_rebuilds_approx_index_equivalently() {
    let dir = temp_dir("kill9_ann");
    let mut cfg = durable_cfg(&dir);
    cfg.epoch = EpochConfig {
        drain_threshold: 256,
        ..EpochConfig::default()
    };
    let live = ServiceState::open(projector(128), &cfg).unwrap();
    let mut g = Pcg64::new(0xA22, 0);
    let n = 3000usize;
    let vec_of = |g: &mut Pcg64| -> Vec<f32> {
        (0..24).map(|_| g.next_f64() as f32 - 0.5).collect()
    };
    let ids: Vec<String> = (0..n).map(|i| format!("v{i:05}")).collect();
    let vectors: Vec<Vec<f32>> = (0..n).map(|_| vec_of(&mut g)).collect();
    match live.handle(Request::RegisterBatch { ids, vectors }) {
        Response::RegisteredBatch { count } => assert_eq!(count, n as u64),
        other => panic!("unexpected {other:?}"),
    }
    // Removes + overwrites so every index-maintenance path fires
    // before the crash point.
    for i in (0..600).step_by(3) {
        match live.handle(Request::Remove {
            id: format!("v{i:05}"),
        }) {
            Response::Removed { existed } => assert!(existed),
            other => panic!("unexpected {other:?}"),
        }
    }
    for i in 0..50 {
        live.handle(Request::Register {
            id: format!("v{:05}", 700 + i),
            vector: vec_of(&mut g),
        });
    }
    // Checkpoint (drains + snapshots); nothing mutates afterwards, so
    // both sides are comparable once the restarted side drains too.
    match live.handle(Request::Persist) {
        Response::Persisted { .. } => {}
        other => panic!("unexpected {other:?}"),
    }

    // kill -9: rebuild purely from disk while the first instance is
    // still alive.
    let restarted = ServiceState::open(projector(128), &cfg).unwrap();
    restarted.default.store.arena().unwrap().drain();
    assert_eq!(dump(&restarted.store), dump(&live.store));
    let live_arena = live.default.store.arena().unwrap();
    let back_arena = restarted.default.store.arena().unwrap();
    assert!(live_arena.index_buckets() > 0);
    assert!(
        back_arena.index_buckets() > 0,
        "recovery must rebuild the banded index from the arena image"
    );

    for qi in 0..5 {
        let v = vec_of(&mut g);
        for probes in [0u32, 2, 4] {
            assert_eq!(
                live.handle(Request::ApproxTopK {
                    vectors: vec![v.clone()],
                    n: 10,
                    probes
                }),
                restarted.handle(Request::ApproxTopK {
                    vectors: vec![v.clone()],
                    n: 10,
                    probes
                }),
                "query {qi} probes {probes}"
            );
        }
    }
    // Approx hits carry exact scores: every returned (id, rho) appears
    // verbatim in the exhaustive exact ranking.
    let v = vec_of(&mut g);
    let exact_all = match live.handle(Request::TopK {
        vectors: vec![v.clone()],
        n: n as u32,
    }) {
        Response::TopK { mut results } => results.pop().unwrap(),
        other => panic!("unexpected {other:?}"),
    };
    let approx = match restarted.handle(Request::ApproxTopK {
        vectors: vec![v],
        n: 10,
        probes: 2,
    }) {
        Response::TopK { mut results } => results.pop().unwrap(),
        other => panic!("unexpected {other:?}"),
    };
    for hit in &approx {
        assert!(
            exact_all.iter().any(|e| e.id == hit.id && e.rho == hit.rho),
            "approx hit {hit:?} must carry its exact score"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite pin: crafted snapshot headers with `bits = 0` (or any
/// unsupported width) and a nonzero count are a clean error on both
/// formats — the legacy loader used to divide by zero.
#[test]
fn recovery_rejects_unsupported_width_headers() {
    let dir = temp_dir("width");
    let path = dir.join("snap.bin");
    for (magic, bad_bits) in [
        (b"CRPSNAP1", 0u32),
        (b"CRPSNAP1", 3),
        (b"CRPSNAP1", 63),
        (b"CRPSNAP2", 0),
        (b"CRPSNAP2", 5),
    ] {
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(magic);
        bytes.extend_from_slice(&64u32.to_le_bytes()); // k
        bytes.extend_from_slice(&bad_bits.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // count/rows > 0
        bytes.extend_from_slice(&4u32.to_le_bytes()); // first record junk
        bytes.extend_from_slice(b"aaaa");
        std::fs::write(&path, &bytes).unwrap();
        let err = snapshot::load(&path).expect_err(&format!("{magic:?}/{bad_bits}"));
        assert!(
            err.to_string().contains("unsupported snapshot bit width"),
            "{magic:?}/{bad_bits}: {err}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
