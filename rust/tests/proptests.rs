//! Property-based tests (proptest is not vendored; these use the crate's
//! own PCG64 to drive randomized cases — shrinkless, but seeds print on
//! failure so cases reproduce exactly).
//!
//! Invariants covered, per the coordinator/coding contract:
//! * routing: estimates are symmetric, identical-input ⇒ ρ̂ = 1
//! * batching: batched execution ≡ one-at-a-time execution
//! * state: packed store round-trips codes exactly
//! * coding: pack/unpack identity, collision count symmetry + bounds,
//!   monotone inversion, expansion inner-product identity
//! * scan: top-k ≡ brute-force sort of per-pair estimator scores,
//!   parallel scan ≡ single-threaded scan, arena mutation round-trips
//! * kernels/epochs (`equiv_*`, also run standalone in CI): every SIMD
//!   tier ≡ the SWAR oracle at all widths and ragged lengths; scans
//!   through the epoch-buffer/sealed-arena split ≡ a fully drained
//!   arena; bulk `put_rows` ≡ per-vector puts; and `put` completes while
//!   a reader holds the sealed side (the seed design deadlocked here)
//! * sparse ingest: a CSR row through the O(nnz·k) gather path stores
//!   byte-identical packed codes to the dense path (all coding widths,
//!   Gaussian and sign-sparse matrices), and TopK over TCP answers
//!   byte-identically whichever path ingested the corpus

use crp::coding::{
    collision_count, collision_count_packed, expand_to_sparse, pack_codes, unpack_codes,
    CodingParams, Scheme,
};
use crp::mathx::Pcg64;
use crp::theory::{InversionTable, SchemeKind};

const CASES: u64 = 60;

fn rng(case: u64) -> Pcg64 {
    Pcg64::new(0xC0FFEE ^ case, case)
}

fn rand_codes(g: &mut Pcg64, n: usize, card: u16) -> Vec<u16> {
    (0..n).map(|_| g.next_below(card as u64) as u16).collect()
}

fn rand_f32s(g: &mut Pcg64, n: usize, scale: f32) -> Vec<f32> {
    (0..n)
        .map(|_| (g.next_f64() as f32 - 0.5) * 2.0 * scale)
        .collect()
}

#[test]
fn prop_pack_unpack_identity() {
    for case in 0..CASES {
        let mut g = rng(case);
        let n = g.next_below(700) as usize;
        let bits = [1u32, 2, 4, 8, 16][g.next_below(5) as usize];
        let card = 1u16 << bits.min(10);
        let codes = rand_codes(&mut g, n, card);
        let packed = pack_codes(&codes, bits);
        assert_eq!(unpack_codes(&packed), codes, "case {case}");
    }
}

#[test]
fn prop_collision_count_invariants() {
    for case in 0..CASES {
        let mut g = rng(case);
        let n = 1 + g.next_below(900) as usize;
        let bits = [1u32, 2, 4, 8][g.next_below(4) as usize];
        let card = 1u16 << bits;
        let a = rand_codes(&mut g, n, card);
        let b = rand_codes(&mut g, n, card);
        let c = collision_count(&a, &b);
        // Symmetry.
        assert_eq!(c, collision_count(&b, &a), "case {case}");
        // Bounds.
        assert!(c <= n);
        // Identity.
        assert_eq!(collision_count(&a, &a), n);
        // Packed agrees with scalar.
        let pa = pack_codes(&a, bits);
        let pb = pack_codes(&b, bits);
        assert_eq!(collision_count_packed(&pa, &pb), c, "case {case}");
    }
}

#[test]
fn prop_encode_code_range() {
    for case in 0..CASES {
        let mut g = rng(case);
        let scheme = SchemeKind::ALL[g.next_below(4) as usize];
        let w = 0.05 + g.next_f64() * 6.0;
        let params = CodingParams::new(scheme, w);
        let xs = rand_f32s(&mut g, 200, 8.0);
        let codes = params.encode(&xs);
        let card = params.cardinality() as u16;
        for &c in &codes {
            assert!(c < card, "case {case}: code {c} >= cardinality {card}");
        }
    }
}

#[test]
fn prop_encode_monotone_in_x_for_interval_schemes() {
    // All four schemes are monotone step functions of x (given fixed
    // offsets) — codes must be non-decreasing along increasing inputs.
    for case in 0..CASES {
        let mut g = rng(case);
        let scheme = SchemeKind::ALL[g.next_below(4) as usize];
        let w = 0.1 + g.next_f64() * 4.0;
        let params = CodingParams::new(scheme, w);
        let mut xs = rand_f32s(&mut g, 100, 7.0);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let offs = vec![0.3 * w; xs.len()];
        let mut codes = vec![0u16; xs.len()];
        params.encode_into(&xs, Some(&offs), &mut codes);
        for win in codes.windows(2) {
            assert!(win[1] >= win[0], "case {case}: non-monotone");
        }
    }
}

#[test]
fn prop_expansion_inner_product_is_collision_rate() {
    for case in 0..CASES / 2 {
        let mut g = rng(case);
        let k = 1 + g.next_below(300) as usize;
        let card = 2 + g.next_below(14) as usize;
        let a = rand_codes(&mut g, k, card as u16);
        let b = rand_codes(&mut g, k, card as u16);
        let (ia, va) = expand_to_sparse(&a, card);
        let (ib, vb) = expand_to_sparse(&b, card);
        let mut dot = 0.0f64;
        let (mut p, mut q) = (0usize, 0usize);
        while p < ia.len() && q < ib.len() {
            match ia[p].cmp(&ib[q]) {
                std::cmp::Ordering::Less => p += 1,
                std::cmp::Ordering::Greater => q += 1,
                std::cmp::Ordering::Equal => {
                    dot += (va[p] * vb[q]) as f64;
                    p += 1;
                    q += 1;
                }
            }
        }
        let rate = collision_count(&a, &b) as f64 / k as f64;
        assert!((dot - rate).abs() < 1e-5, "case {case}: {dot} vs {rate}");
    }
}

#[test]
fn prop_inversion_table_monotone_and_inverse() {
    for case in 0..16 {
        let mut g = rng(case);
        let scheme = SchemeKind::ALL[g.next_below(4) as usize];
        let w = 0.2 + g.next_f64() * 3.0;
        let table = InversionTable::build(scheme, w, 512);
        // Monotone: ρ̂ non-decreasing in the empirical rate.
        let mut prev = -1.0;
        for i in 0..=50 {
            let p = i as f64 / 50.0;
            let rho = table.rho(p);
            assert!(rho >= prev - 1e-12, "case {case}");
            assert!((0.0..=1.0).contains(&rho));
            prev = rho;
        }
        // Inverse: table(P(ρ)) ≈ ρ.
        for i in 1..10 {
            let rho = i as f64 / 10.0;
            let p = scheme.collision_probability(rho, w);
            assert!(
                (table.rho(p) - rho).abs() < 5e-3,
                "case {case} scheme {scheme:?} rho {rho}"
            );
        }
    }
}

#[test]
fn prop_scan_topk_matches_bruteforce_estimator_sort() {
    use crp::estimator::CollisionEstimator;
    use crp::scan::{scan_topk, CodeArena};

    for case in 0..CASES / 2 {
        let mut g = rng(0xA11CE ^ case);
        // (bits, scheme) pairs whose packed width matches the scheme's
        // cardinality, so estimator inversion applies directly.
        let (bits, scheme, w) = [
            (1u32, SchemeKind::OneBit, 0.0),
            (2, SchemeKind::TwoBit, 0.75),
            (4, SchemeKind::Uniform, 0.75),
        ][g.next_below(3) as usize];
        let card = 1u16 << bits;
        let k = 16 + g.next_below(260) as usize;
        let n_rows = 1 + g.next_below(250) as usize;
        let top = g.next_below(20) as usize;
        let mut arena = CodeArena::new(k, bits);
        let mut raw = Vec::new();
        for i in 0..n_rows {
            let codes = rand_codes(&mut g, k, card);
            arena.insert(&format!("r{i:05}"), &pack_codes(&codes, bits));
            raw.push(codes);
        }
        let qcodes = rand_codes(&mut g, k, card);
        let q = pack_codes(&qcodes, bits);
        let got = scan_topk(&arena, &q, top, 1);

        // Brute force: score every pair with the estimator, sort the
        // scores (ρ̂ is monotone in the collision count; ties resolved
        // by id as the estimator path does), truncate.
        let est = CollisionEstimator::new(CodingParams::new(scheme, w));
        let mut want: Vec<(String, usize, f64)> = raw
            .iter()
            .enumerate()
            .map(|(i, codes)| {
                let c = collision_count(codes, &qcodes);
                (format!("r{i:05}"), c, est.estimate_from_count(c, k))
            })
            .collect();
        want.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        want.truncate(top);

        assert_eq!(got.len(), want.len(), "case {case}");
        for (hit, (id, c, rho)) in got.iter().zip(&want) {
            assert_eq!(&hit.id, id, "case {case}");
            assert_eq!(hit.collisions, *c, "case {case}");
            assert_eq!(est.estimate_from_count(hit.collisions, k), *rho, "case {case}");
        }
        // ρ̂ ordering is non-increasing down the ranking.
        for pair in want.windows(2) {
            assert!(pair[0].2 >= pair[1].2, "case {case}");
        }

        // Parallel scan ≡ single-threaded scan, row-sharded and batched.
        let threads = 2 + g.next_below(5) as usize;
        assert_eq!(got, scan_topk(&arena, &q, top, threads), "case {case}");
        let batch = crp::scan::scan_topk_batch(&arena, &[q.clone(), q], top, threads);
        assert_eq!(batch.len(), 2, "case {case}");
        assert_eq!(batch[0], got, "case {case}");
        assert_eq!(batch[1], got, "case {case}");
    }
}

#[test]
fn equiv_simd_kernels_match_swar_all_widths() {
    use crp::scan::{CollisionKernel, KernelKind};
    // Widths × lengths spanning SIMD blocks (AVX2 1-bit step = 256
    // codes), word boundaries, ragged partial words, and k = 1.
    for &(bits, card) in &[(1u32, 2u16), (2, 4), (4, 16), (8, 200), (16, 999)] {
        for &k in &[1usize, 31, 32, 63, 64, 65, 127, 128, 255, 256, 257, 300, 1024, 1027] {
            let mut g = Pcg64::new(0x51D ^ ((bits as u64) << 20) ^ k as u64, 1);
            let a = rand_codes(&mut g, k, card);
            let b = rand_codes(&mut g, k, card);
            let pa = pack_codes(&a, bits);
            let pb = pack_codes(&b, bits);
            let zeros = vec![0u16; k];
            let pz = pack_codes(&zeros, bits); // an "empty" (all-zero) row
            let want = collision_count(&a, &b);
            let want_zero = collision_count(&a, &zeros);
            for kind in KernelKind::ALL {
                let Some(kernel) = CollisionKernel::with_kind(bits, kind) else {
                    continue; // tier absent on this CPU / at this width
                };
                assert_eq!(
                    kernel.count(k, pa.words(), pb.words()),
                    want,
                    "bits={bits} k={k} kind={kind:?}"
                );
                assert_eq!(
                    kernel.count(k, pa.words(), pa.words()),
                    k,
                    "self bits={bits} k={k} kind={kind:?}"
                );
                assert_eq!(
                    kernel.count(k, pa.words(), pz.words()),
                    want_zero,
                    "zero-row bits={bits} k={k} kind={kind:?}"
                );
            }
        }
    }
}

#[test]
fn equiv_epoch_scan_matches_fully_drained() {
    use crp::scan::{scan_topk, EpochArena, EpochConfig};
    use std::collections::HashMap;

    for case in 0..CASES / 3 {
        let mut g = rng(0xE90C ^ case);
        let bits = [1u32, 2, 4][g.next_below(3) as usize];
        let card = 1u16 << bits;
        let k = 8 + g.next_below(200) as usize;
        // Tiny thresholds so epochs roll over and compaction fires
        // mid-sequence.
        let epoch = EpochArena::with_config(
            k,
            bits,
            EpochConfig {
                drain_threshold: 8 + g.next_below(40) as usize,
                compact_ratio: 0.3,
                compact_min: 4,
            },
        );
        let mut model: HashMap<String, Vec<u16>> = HashMap::new();
        let universe = 30;
        for step in 0..250 {
            let id = format!("id{:02}", g.next_below(universe));
            match g.next_below(5) {
                0 => {
                    let in_model = model.remove(&id).is_some();
                    assert_eq!(epoch.remove(&id), in_model, "case {case} step {step}");
                }
                1 if g.next_below(8) == 0 => {
                    epoch.drain();
                }
                _ => {
                    let codes = rand_codes(&mut g, k, card);
                    if epoch.put(&id, &pack_codes(&codes, bits)) {
                        epoch.drain();
                    }
                    model.insert(id, codes);
                }
            }
        }
        assert_eq!(epoch.len(), model.len(), "case {case}");
        for (id, codes) in &model {
            let got = epoch.get(id).unwrap_or_else(|| panic!("case {case}: {id} missing"));
            assert_eq!(crp::coding::unpack_codes(&got), *codes, "case {case}: {id}");
        }
        // Scan through the epoch split ≡ brute force over the live set.
        let q = rand_codes(&mut g, k, card);
        let pq = pack_codes(&q, bits);
        let top = 1 + g.next_below(12) as usize;
        let got: Vec<(String, usize)> = epoch
            .scan_topk(&pq, top, 1)
            .into_iter()
            .map(|h| (h.id, h.collisions))
            .collect();
        let mut want: Vec<(String, usize)> = model
            .iter()
            .map(|(id, codes)| (id.clone(), collision_count(codes, &q)))
            .collect();
        want.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        want.truncate(top);
        assert_eq!(got, want, "case {case}");
        // Batched and threaded epoch scans agree with the serial one.
        let batch = epoch.scan_topk_batch(&[pq.clone(), pq.clone()], top, 3);
        assert_eq!(batch.len(), 2, "case {case}");
        for hits in &batch {
            let hits: Vec<(String, usize)> =
                hits.iter().map(|h| (h.id.clone(), h.collisions)).collect();
            assert_eq!(hits, want, "case {case}");
        }
        // After a full drain the sealed arena alone must rank the same.
        epoch.drain();
        let drained: Vec<(String, usize)> = epoch.with_sealed(|sealed| {
            scan_topk(sealed, &pq, top, 1)
                .into_iter()
                .map(|h| (h.id, h.collisions))
                .collect()
        });
        assert_eq!(drained, want, "case {case}");
    }
}

#[test]
fn equiv_bulk_put_rows_matches_per_vector_puts() {
    use crp::coordinator::store::SketchStore;
    let (k, bits) = (96usize, 2u32);
    let singles = SketchStore::with_arena(k, bits);
    let bulk = SketchStore::with_arena(k, bits);
    let stride = bulk.arena().unwrap().stride();
    let mut g = rng(0xB17);
    let mut ids = Vec::new();
    let mut words = Vec::new();
    for i in 0..50 {
        let codes = rand_codes(&mut g, k, 4);
        let packed = pack_codes(&codes, bits);
        singles.put(format!("v{i:02}"), packed.clone());
        ids.push(format!("v{i:02}"));
        words.extend_from_slice(packed.words());
    }
    assert_eq!(words.len(), 50 * stride);
    bulk.put_rows(&ids, &words).unwrap();
    assert_eq!(singles.len(), bulk.len());
    for id in &ids {
        assert_eq!(singles.get(id), bulk.get(id), "{id}");
        assert_eq!(
            singles.arena().unwrap().get(id),
            bulk.arena().unwrap().get(id),
            "{id}"
        );
    }
    let q = pack_codes(&rand_codes(&mut g, k, 4), bits);
    let strip = |hits: Vec<crp::scan::ScanHit>| -> Vec<(String, usize)> {
        hits.into_iter().map(|h| (h.id, h.collisions)).collect()
    };
    assert_eq!(
        strip(singles.arena().unwrap().scan_topk(&q, 10, 1)),
        strip(bulk.arena().unwrap().scan_topk(&q, 10, 1))
    );
}

#[test]
fn equiv_put_completes_while_scan_holds_the_read_side() {
    use crp::coordinator::store::SketchStore;
    use crp::scan::EpochConfig;
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::time::Duration;

    // A tiny drain threshold so the writer crosses it many times while
    // the read side is held — the fold must be skipped (try-lock), not
    // waited on. The write volume (51) stays under the relief cap
    // (RELIEF_FACTOR × 8 = 64), where a blocking fold is allowed.
    let store = Arc::new(SketchStore::with_arena_config(
        64,
        2,
        EpochConfig {
            drain_threshold: 8,
            ..EpochConfig::default()
        },
    ));
    let mut g = rng(0xB10C);
    for i in 0..100 {
        store.put(format!("seed{i:03}"), pack_codes(&rand_codes(&mut g, 64, 4), 2));
    }
    store.arena().unwrap().drain();

    // A reader parks on the sealed side (what a long scan shard holds).
    let (locked_tx, locked_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    let reader = store.clone();
    let reader_thread = std::thread::spawn(move || {
        reader.arena().unwrap().with_sealed(|sealed| {
            locked_tx.send(sealed.len()).unwrap();
            release_rx.recv().unwrap();
        });
    });
    assert_eq!(locked_rx.recv().unwrap(), 100);

    // The seed design took the arena *write* lock on every put, so this
    // would block until the reader finished. The epoch path must land
    // all writes — including the threshold-crossing ones — while the
    // read side stays held.
    let (done_tx, done_rx) = mpsc::channel();
    let writer = store.clone();
    let codes = pack_codes(&rand_codes(&mut g, 64, 4), 2);
    let writer_thread = std::thread::spawn(move || {
        for i in 0..50 {
            writer.put(format!("live{i:02}"), codes.clone());
        }
        assert!(writer.remove("seed000"));
        done_tx.send(()).unwrap();
    });
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("puts blocked behind a held scan read lock");
    // Scans keep seeing every write even though no fold could run.
    assert_eq!(store.arena().unwrap().len(), 149);
    release_tx.send(()).unwrap();
    reader_thread.join().unwrap();
    writer_thread.join().unwrap();
    assert_eq!(store.len(), 100 + 50 - 1);
    // With the read side free again, the next threshold crossing folds.
    store.arena().unwrap().drain();
    assert_eq!(store.arena().unwrap().len(), 149);
    assert_eq!(store.arena().unwrap().pending_load(), 0);
}

#[test]
fn prop_arena_mutation_roundtrip() {
    use crp::scan::{scan_topk, CodeArena};
    use std::collections::HashMap;

    for case in 0..CASES / 3 {
        let mut g = rng(0xDEAD ^ case);
        let bits = [1u32, 2, 4][g.next_below(3) as usize];
        let card = 1u16 << bits;
        let k = 8 + g.next_below(150) as usize;
        let mut arena = CodeArena::new(k, bits);
        let mut model: HashMap<String, Vec<u16>> = HashMap::new();
        let universe = 40;
        for _ in 0..300 {
            let id = format!("id{:02}", g.next_below(universe));
            match g.next_below(4) {
                0 => {
                    arena.remove(&id);
                    model.remove(&id);
                }
                3 if g.next_below(10) == 0 => {
                    arena.compact();
                }
                _ => {
                    let codes = rand_codes(&mut g, k, card);
                    arena.insert(&id, &pack_codes(&codes, bits));
                    model.insert(id, codes);
                }
            }
        }
        assert_eq!(arena.len(), model.len(), "case {case}");
        for (id, codes) in &model {
            let stored = arena.get(id).unwrap_or_else(|| panic!("case {case}: {id} missing"));
            assert_eq!(unpack_codes(&stored), *codes, "case {case}: {id}");
        }
        // Compaction preserves exactly the live set and its codes.
        arena.compact();
        assert_eq!(arena.tombstones(), 0, "case {case}");
        assert_eq!(arena.len(), model.len(), "case {case}");
        assert_eq!(arena.rows_allocated(), model.len(), "case {case}");
        for (id, codes) in &model {
            assert_eq!(unpack_codes(&arena.get(id).unwrap()), *codes, "case {case}: {id}");
        }
        // A full scan sees every live row exactly once.
        if !model.is_empty() {
            let q = pack_codes(&rand_codes(&mut g, k, card), bits);
            let hits = scan_topk(&arena, &q, model.len() + 5, 1);
            assert_eq!(hits.len(), model.len(), "case {case}");
            let mut seen: Vec<&str> = hits.iter().map(|h| h.id.as_str()).collect();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), model.len(), "case {case}");
        }
    }
}

#[test]
fn prop_service_knn_identical_to_bruteforce_scan() {
    use crp::coordinator::protocol::{Request, Response};
    use crp::coordinator::server::{ServerConfig, ServiceState};
    use crp::projection::{ProjectionConfig, Projector};
    use std::sync::Arc;

    let state = ServiceState::new(
        Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 192,
            seed: 6,
            ..Default::default()
        })),
        &ServerConfig::default(),
    );
    let mut g = rng(21);
    for i in 0..80 {
        let v = rand_f32s(&mut g, 40, 1.0);
        state.handle(Request::Register {
            id: format!("v{i:03}"),
            vector: v,
        });
    }
    for case in 0..6 {
        let qv = rand_f32s(&mut g, 40, 1.0);
        // The batcher is deterministic: registering the query stores the
        // same sketch Knn computes internally.
        let qid = format!("query{case}");
        state.handle(Request::Register {
            id: qid.clone(),
            vector: qv.clone(),
        });
        let qs = state.store.get(&qid).unwrap();
        let mut want: Vec<(String, usize)> = Vec::new();
        state.store.for_each(|id, codes| {
            want.push((
                id.to_string(),
                crp::coding::collision_count_packed(&qs, codes),
            ));
        });
        want.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        want.truncate(7);
        match state.handle(Request::Knn { vector: qv, n: 7 }) {
            Response::Knn { hits } => {
                assert_eq!(hits.len(), want.len(), "case {case}");
                for (hit, (id, c)) in hits.iter().zip(&want) {
                    assert_eq!(&hit.id, id, "case {case}");
                    assert_eq!(
                        hit.rho,
                        state.estimator.estimate_from_count(*c, state.k),
                        "case {case}"
                    );
                }
            }
            other => panic!("case {case}: {other:?}"),
        }
    }
}

#[test]
fn prop_service_routing_invariants() {
    use crp::coordinator::protocol::{Request, Response};
    use crp::coordinator::server::{ServerConfig, ServiceState};
    use crp::projection::{ProjectionConfig, Projector};
    use std::sync::Arc;

    let state = ServiceState::new(
        Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 256,
            seed: 2,
            ..Default::default()
        })),
        &ServerConfig::default(),
    );
    let mut g = rng(1);
    for case in 0..10 {
        let v = rand_f32s(&mut g, 64, 1.0);
        let w = rand_f32s(&mut g, 64, 1.0);
        state.handle(Request::Register {
            id: format!("a{case}"),
            vector: v.clone(),
        });
        state.handle(Request::Register {
            id: format!("b{case}"),
            vector: w,
        });
        // Symmetry of estimates.
        let ab = match state.handle(Request::Estimate {
            a: format!("a{case}"),
            b: format!("b{case}"),
        }) {
            Response::Estimate { rho, .. } => rho,
            other => panic!("{other:?}"),
        };
        let ba = match state.handle(Request::Estimate {
            a: format!("b{case}"),
            b: format!("a{case}"),
        }) {
            Response::Estimate { rho, .. } => rho,
            other => panic!("{other:?}"),
        };
        assert_eq!(ab, ba, "case {case}");
        // Self-similarity: re-register the identical vector.
        state.handle(Request::Register {
            id: format!("a{case}-dup"),
            vector: v,
        });
        let self_rho = match state.handle(Request::Estimate {
            a: format!("a{case}"),
            b: format!("a{case}-dup"),
        }) {
            Response::Estimate { rho, .. } => rho,
            other => panic!("{other:?}"),
        };
        assert!(self_rho > 0.999, "case {case}: self rho {self_rho}");
    }
}

#[test]
fn prop_batched_equals_sequential() {
    use crp::coordinator::batcher::{BatcherConfig, SketchBatcher};
    use crp::coordinator::metrics::Metrics;
    use crp::projection::{ProjectionConfig, Projector};
    use std::sync::Arc;
    use std::time::Duration;

    let cfg = ProjectionConfig {
        k: 64,
        seed: 8,
        ..Default::default()
    };
    let direct_proj = Projector::new_cpu(cfg.clone());
    let coding = CodingParams::new(Scheme::TwoBit, 0.75);
    let batcher = SketchBatcher::spawn(
        Arc::new(Projector::new_cpu(cfg)),
        coding.clone(),
        BatcherConfig {
            max_batch: 7, // deliberately odd to force mixed batch sizes
            max_delay: Duration::from_millis(4),
            idle_flush: Duration::from_micros(500),
        },
        Arc::new(Metrics::default()),
    );
    let mut g = rng(7);
    let vecs: Vec<Vec<f32>> = (0..23)
        .map(|_| {
            let n = 50 + g.next_below(100) as usize;
            rand_f32s(&mut g, n, 1.0)
        })
        .collect();
    // Concurrent submission (mixed into shared batches)...
    let handles: Vec<_> = vecs
        .iter()
        .map(|v| {
            let b = batcher.clone();
            let v = v.clone();
            std::thread::spawn(move || b.sketch(v).unwrap())
        })
        .collect();
    let batched: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // ...must equal isolated projection + coding.
    for (v, got) in vecs.iter().zip(&batched) {
        let x = direct_proj.project_dense(v);
        let want = pack_codes(&coding.encode(&x), coding.bits_per_code());
        assert_eq!(*got, want);
    }
}

/// Random sparse rows: strictly increasing indices over `cols` columns
/// (each column kept with probability ~1/4), plus the densified copies.
fn rand_sparse_rows(
    g: &mut Pcg64,
    rows: usize,
    cols: usize,
) -> (crp::data::CsrMatrix, Vec<Vec<f32>>) {
    let mut csr = crp::data::CsrMatrix::with_capacity(rows, 0, cols);
    let mut dense = Vec::with_capacity(rows);
    let (mut idx, mut val) = (Vec::new(), Vec::new());
    for _ in 0..rows {
        idx.clear();
        val.clear();
        let mut d = vec![0.0f32; cols];
        for c in 0..cols {
            if g.next_below(4) == 0 {
                let v = (g.next_f64() as f32 - 0.5) * 2.0;
                idx.push(c as u32);
                val.push(v);
                d[c] = v;
            }
        }
        csr.push_row(&idx, &val);
        dense.push(d);
    }
    (csr, dense)
}

#[test]
fn prop_register_sparse_codes_byte_identical_to_dense() {
    use crp::coordinator::protocol::{Request, Response};
    use crp::coordinator::server::{ServerConfig, ServiceState};
    use crp::projection::{MatrixKind, ProjectionConfig, Projector};
    use std::sync::Arc;

    // The tentpole pin: a CSR row through the O(nnz·k) gather path must
    // store the exact packed bytes the dense O(d·k) path stores — for
    // every coding width and for both matrix families.
    let mut case = 0u64;
    for (scheme, w) in [
        (Scheme::OneBit, 0.0),
        (Scheme::TwoBit, 0.75),
        (Scheme::Uniform, 0.75),
    ] {
        for kind in [MatrixKind::Gaussian, MatrixKind::SignSparse { s: 3 }] {
            let cfg = ServerConfig {
                coding: CodingParams::new(scheme, w),
                ..Default::default()
            };
            let state = ServiceState::new(
                Arc::new(Projector::new_cpu(ProjectionConfig {
                    k: 96,
                    seed: 5,
                    kind,
                    ..Default::default()
                })),
                &cfg,
            );
            for _ in 0..6 {
                let mut g = rng(0x5BA12E ^ case);
                let rows = 1 + g.next_below(12) as usize;
                let cols = 1 + g.next_below(300) as usize;
                let (csr, dense) = rand_sparse_rows(&mut g, rows, cols);
                for (i, d) in dense.iter().enumerate() {
                    state.handle(Request::Register {
                        id: format!("d{case}-{i}"),
                        vector: d.clone(),
                    });
                }
                let ids: Vec<String> =
                    (0..rows).map(|i| format!("s{case}-{i}")).collect();
                match state.handle(Request::RegisterSparse { ids, csr }) {
                    Response::RegisteredBatch { count } => {
                        assert_eq!(count, rows as u64, "case {case}")
                    }
                    other => panic!("case {case}: {other:?}"),
                }
                for i in 0..rows {
                    let ds = state.store.get(&format!("d{case}-{i}"));
                    let ss = state.store.get(&format!("s{case}-{i}"));
                    assert!(ds.is_some(), "case {case} row {i}");
                    assert_eq!(
                        ds, ss,
                        "case {case} row {i}: sparse codes != dense codes \
                         (scheme {scheme:?}, kind {kind:?})"
                    );
                }
                case += 1;
            }
        }
    }
}

#[test]
fn prop_sparse_ingest_topk_over_tcp_matches_dense_ingest() {
    use crp::coordinator::protocol::{read_frame_into, write_frame, Request};
    use crp::coordinator::server::{serve, ServerConfig};
    use crp::projection::{ProjectionConfig, Projector};
    use std::io::BufReader;
    use std::net::TcpStream;
    use std::sync::Arc;

    // Two identically-configured thread-mode servers: one ingests the
    // densified rows over RegisterBatch, the other the CSR triplets
    // over RegisterSparse. Every subsequent TopK answer must come back
    // byte-identical, across the 1/2/4-bit schemes.
    let spawn = |scheme: Scheme, w: f64| -> String {
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 64,
            seed: 9,
            ..Default::default()
        }));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            coding: CodingParams::new(scheme, w),
            ..Default::default()
        };
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let _ = serve(projector, cfg, Some(tx));
        });
        rx.recv().expect("server failed to bind").to_string()
    };
    let ask = |addr: &str, reqs: &[Request]| -> Vec<Vec<u8>> {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut frames = Vec::with_capacity(reqs.len());
        let mut frame = Vec::new();
        for req in reqs {
            write_frame(&mut stream, &req.encode()).unwrap();
            read_frame_into(&mut reader, &mut frame).unwrap();
            frames.push(frame.clone());
        }
        frames
    };

    for (case, (scheme, w)) in [
        (Scheme::OneBit, 0.0),
        (Scheme::TwoBit, 0.75),
        (Scheme::Uniform, 0.75),
    ]
    .into_iter()
    .enumerate()
    {
        let mut g = rng(0x7C9 ^ case as u64);
        let rows = 40usize;
        let cols = 48usize;
        let (csr, dense) = rand_sparse_rows(&mut g, rows, cols);
        let ids: Vec<String> = (0..rows).map(|i| format!("r{i:03}")).collect();
        let queries: Vec<Request> = (0..5)
            .map(|_| Request::TopK {
                vectors: vec![rand_f32s(&mut g, cols, 1.0)],
                n: 8,
            })
            .collect();

        let addr_dense = spawn(scheme, w);
        let addr_sparse = spawn(scheme, w);
        ask(
            &addr_dense,
            &[Request::RegisterBatch {
                ids: ids.clone(),
                vectors: dense,
            }],
        );
        ask(&addr_sparse, &[Request::RegisterSparse { ids, csr }]);
        let a = ask(&addr_dense, &queries);
        let b = ask(&addr_sparse, &queries);
        assert_eq!(
            a, b,
            "case {case}: TopK diverged between dense and sparse ingest \
             (scheme {scheme:?})"
        );
    }
}

#[test]
fn prop_store_roundtrip_exact() {
    use crp::coordinator::store::SketchStore;
    let store = SketchStore::new();
    let mut g = rng(3);
    let mut originals = Vec::new();
    for i in 0..200 {
        let n = 1 + g.next_below(300) as usize;
        let codes = rand_codes(&mut g, n, 4);
        let packed = pack_codes(&codes, 2);
        store.put(format!("id-{i}"), packed.clone());
        originals.push((format!("id-{i}"), packed));
    }
    for (id, want) in &originals {
        assert_eq!(store.get(id).as_ref(), Some(want));
    }
    assert_eq!(store.len(), 200);
}

#[test]
fn prop_protocol_decode_never_panics_on_garbage() {
    use crp::coordinator::protocol::{Request, Response};
    let mut g = rng(99);
    for case in 0..400 {
        let n = g.next_below(200) as usize;
        let bytes: Vec<u8> = (0..n).map(|_| g.next_below(256) as u8).collect();
        // Must return Err or Ok — never panic.
        let _ = Request::decode(&bytes);
        let _ = Response::decode(&bytes);
        // Truncations of valid messages must also be handled.
        let valid = Request::Register {
            id: format!("id-{case}"),
            vector: vec![1.0; (case % 7) as usize],
        }
        .encode();
        for cut in 0..valid.len() {
            let _ = Request::decode(&valid[..cut]);
        }
    }
}

#[test]
fn prop_snapshot_roundtrip_via_service() {
    use crp::coordinator::durability::snapshot::{load, save};
    use crp::coordinator::protocol::{Request, Response};
    use crp::coordinator::server::{ServerConfig, ServiceState};
    use crp::projection::{ProjectionConfig, Projector};
    use std::sync::Arc;

    let cfg = ServerConfig::default();
    let mk_state = || {
        ServiceState::new(
            Arc::new(Projector::new_cpu(ProjectionConfig {
                k: 128,
                seed: 4,
                ..Default::default()
            })),
            &cfg,
        )
    };
    let state = mk_state();
    let mut g = rng(13);
    for i in 0..40 {
        let v = rand_f32s(&mut g, 64, 1.0);
        state.handle(Request::Register {
            id: format!("s{i}"),
            vector: v,
        });
    }
    let path = std::env::temp_dir().join(format!("crp_svc_snap_{}.bin", std::process::id()));
    // Checkpoint shape: drain the epoch, then image the sealed arena.
    let arena = state.store.arena().expect("service store is arena-backed");
    arena.drain();
    save(&path, &arena.sealed_image()).unwrap();
    // Restore into a fresh service; estimates must be identical since
    // the sketches (not the raw vectors) are the state.
    let restored = ServiceState::with_snapshot(
        Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 128,
            seed: 4,
            ..Default::default()
        })),
        &cfg,
        &path,
    )
    .unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(restored.store.len(), 40);
    for (a, b) in [("s0", "s1"), ("s5", "s17"), ("s30", "s39")] {
        let before = match state.handle(Request::Estimate {
            a: a.into(),
            b: b.into(),
        }) {
            Response::Estimate { rho, .. } => rho,
            other => panic!("{other:?}"),
        };
        let after = match restored.handle(Request::Estimate {
            a: a.into(),
            b: b.into(),
        }) {
            Response::Estimate { rho, .. } => rho,
            other => panic!("{other:?}"),
        };
        assert_eq!(before, after, "{a}/{b}");
    }
    // Sanity: the snapshot loader agrees on shape metadata, and the
    // restored service's own image round-trips identically.
    let p2 = std::env::temp_dir().join(format!("crp_svc_snap2_{}.bin", std::process::id()));
    let arena2 = restored.store.arena().expect("arena-backed");
    arena2.drain();
    save(&p2, &arena2.sealed_image()).unwrap();
    let img = load(&p2).unwrap();
    std::fs::remove_file(&p2).ok();
    assert_eq!(img.k, 128);
    assert_eq!(img.bits, cfg.coding.bits_per_code());
    assert_eq!(img.live(), 40);
}
