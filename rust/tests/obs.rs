//! Observability tests: StatsDetailed per-collection aggregation staying
//! consistent under concurrent multi-collection ingest, the Prometheus
//! exposition page tracking the collection lifecycle over TCP, and the
//! slow-query counter firing end to end.
//!
//! Run standalone with `cargo test --release -q obs` (CI does).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crp::coding::Scheme;
use crp::coordinator::server::{serve, ServerConfig};
use crp::coordinator::SketchClient;
use crp::mathx::Pcg64;
use crp::projection::{ProjectionConfig, Projector};

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("crp_obs_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn spawn_server(cfg: ServerConfig, k: usize) -> String {
    let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
        k,
        seed: 7,
        ..Default::default()
    }));
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = serve(projector, cfg, Some(tx));
    });
    rx.recv()
        .expect("server thread exited before reporting its bound address")
        .to_string()
}

fn vec_of(g: &mut Pcg64, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| g.next_f64() as f32 - 0.5).collect()
}

/// The value of an unlabeled (or exactly-labeled) series on the
/// exposition page, e.g. `metric_value(&text, "crp_slow_queries_total")`.
fn metric_value(text: &str, series: &str) -> Option<u64> {
    text.lines().find_map(|l| {
        l.strip_prefix(series)
            .and_then(|rest| rest.strip_prefix(' '))
            .and_then(|v| v.trim().parse::<f64>().ok())
            .map(|v| v as u64)
    })
}

/// Satellite pin: per-collection rows in `StatsDetailed` aggregate
/// exactly — after concurrent ingest across two durable collections
/// quiesces, the per-collection rows/pending/wal_bytes sum to the
/// aggregates, and the per-request table carries an exact register
/// count. Mid-ingest snapshots must stay well-formed (both collections
/// present, sorted, counters monotone) even while writers race drains.
#[test]
fn stats_detailed_aggregation_under_concurrent_ingest() {
    let dir = temp_dir("agg");
    let addr = spawn_server(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            data_dir: Some(dir.clone()),
            epoch: crp::scan::EpochConfig {
                drain_threshold: 32,
                ..Default::default()
            },
            checkpoint_every: 0,
            ..Default::default()
        },
        64,
    );
    let mut admin = SketchClient::connect(&addr).unwrap();
    admin.create_collection("web", Scheme::OneBit, 0.0, 64, 3, 0).unwrap();

    const THREADS: usize = 3;
    const PER_THREAD: usize = 120;
    let mut workers = Vec::new();
    for t in 0..THREADS {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let mut c = SketchClient::connect(&addr).unwrap();
            let mut g = Pcg64::new(t as u64, 1);
            for i in 0..PER_THREAD {
                c.register_in(None, &format!("d{t}-{i}"), vec_of(&mut g, 16)).unwrap();
                c.register_in(Some("web"), &format!("w{t}-{i}"), vec_of(&mut g, 16)).unwrap();
            }
        }));
    }

    // Mid-ingest snapshots race writers and maintenance drains; they
    // must decode and stay internally plausible, never exact.
    let mut last_registered = 0u64;
    for _ in 0..10 {
        let st = admin.stats_detailed().unwrap();
        assert_eq!(st.per_collection.len(), 2);
        assert_eq!(st.per_collection[0].name, "default");
        assert_eq!(st.per_collection[1].name, "web");
        assert!(st.registered >= last_registered, "registered went backwards");
        last_registered = st.registered;
        std::thread::sleep(Duration::from_millis(5));
    }
    for w in workers {
        w.join().unwrap();
    }

    // Quiesce: no writers are left, so the only movement is the
    // maintenance thread folding the backlog down below the threshold.
    let total = (2 * THREADS * PER_THREAD) as u64;
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let st = loop {
        let a = admin.stats_detailed().unwrap();
        std::thread::sleep(Duration::from_millis(20));
        let b = admin.stats_detailed().unwrap();
        if a.pending_rows == b.pending_rows && a.drains == b.drains {
            break b;
        }
        assert!(std::time::Instant::now() < deadline, "drains never quiesced");
    };
    assert_eq!(st.registered, total);
    assert_eq!(st.collections, 2);
    let (mut rows, mut pending, mut wal) = (0, 0, 0);
    for c in &st.per_collection {
        assert_eq!(c.rows, (THREADS * PER_THREAD) as u64, "{}", c.name);
        assert!(c.index_buckets > 0, "{} never folded into its index", c.name);
        assert!(c.wal_bytes > 0, "{} is durable; ingest must hit its WAL", c.name);
        rows += c.rows;
        pending += c.pending_rows;
        wal += c.wal_bytes;
    }
    assert_eq!(rows, total, "per-collection rows must sum to the aggregate");
    assert_eq!(pending, st.pending_rows);
    assert_eq!(wal, st.wal_bytes);

    // Full-path latency reached the per-request table: the register row
    // counts every wire register exactly, and its percentiles are sane.
    let reg = st
        .per_request
        .iter()
        .find(|r| r.kind == "register")
        .expect("register row missing from per_request");
    assert_eq!(reg.count, total);
    assert!(reg.p50_us >= 1 && reg.p99_us >= reg.p50_us);
    // The stats polls themselves are admin-kind requests.
    assert!(st.per_request.iter().any(|r| r.kind == "admin"));
    std::fs::remove_dir_all(&dir).ok();
}

/// The exposition page follows the collection lifecycle: series appear
/// on create+ingest, vanish on drop, and come back when the name is
/// reused — all over the `MetricsText` protocol request.
#[test]
fn metrics_text_tracks_collection_lifecycle() {
    let addr = spawn_server(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        64,
    );
    let mut c = SketchClient::connect(&addr).unwrap();
    c.create_collection("tmp", Scheme::TwoBit, 0.75, 64, 9, 0).unwrap();
    let mut g = Pcg64::new(17, 4);
    for i in 0..8 {
        c.register_in(Some("tmp"), &format!("t{i}"), vec_of(&mut g, 16)).unwrap();
    }

    let text = c.metrics_text().unwrap();
    assert!(text.contains("# TYPE crp_collection_rows gauge"), "{text}");
    assert!(text.contains("crp_collection_rows{collection=\"default\"} 0"), "{text}");
    assert!(text.contains("crp_collection_rows{collection=\"tmp\"} 8"), "{text}");
    assert!(text.contains("crp_requests_total{kind=\"register\"} 8"), "{text}");
    assert!(
        text.contains("crp_request_duration_us_count{kind=\"register\"} 8"),
        "{text}"
    );

    assert!(c.drop_collection("tmp").unwrap());
    let text = c.metrics_text().unwrap();
    assert!(
        !text.contains("collection=\"tmp\""),
        "dropped collection must leave the page: {text}"
    );
    assert!(text.contains("crp_collections 1"), "{text}");

    // Reusing the name starts fresh series.
    c.create_collection("tmp", Scheme::OneBit, 0.0, 32, 2, 0).unwrap();
    c.register_in(Some("tmp"), "back", vec_of(&mut g, 16)).unwrap();
    let text = c.metrics_text().unwrap();
    assert!(text.contains("crp_collection_rows{collection=\"tmp\"} 1"), "{text}");
}

/// `--slow-query-us 1` classifies every request as slow; the counter on
/// the exposition page must count each one, end to end over TCP.
#[test]
fn slow_query_threshold_counts_every_request() {
    let addr = spawn_server(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            slow_query_us: 1,
            // Keep the warn-per-request flood out of the test log. The
            // level is process-global, so this also quiets concurrent
            // tests' servers — fine, since no test asserts on stderr.
            log_level: Some("error".into()),
            ..Default::default()
        },
        64,
    );
    let mut c = SketchClient::connect(&addr).unwrap();
    let mut g = Pcg64::new(23, 6);
    for i in 0..5 {
        c.register_in(None, &format!("s{i}"), vec_of(&mut g, 16)).unwrap();
    }
    c.knn_in(None, vec_of(&mut g, 16), 3).unwrap();
    let text = c.metrics_text().unwrap();
    let slow = metric_value(&text, "crp_slow_queries_total").expect("counter missing");
    assert!(slow >= 6, "6 requests went through, counted {slow}: {text}");
}

/// The slow-query ring under concurrency: writers flooding the ring
/// (every request is "slow" at a 1 us threshold) race readers pulling
/// `SlowQueries` snapshots over TCP. Every snapshot must be internally
/// consistent — bounded by the ring cap, strictly ordered by seq, and
/// made of fully-formed entries — never a torn or half-written one.
#[test]
fn slow_query_ring_snapshots_never_tear() {
    let addr = spawn_server(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            slow_query_us: 1,
            log_level: Some("error".into()),
            ..Default::default()
        },
        64,
    );

    const WRITERS: usize = 3;
    const PER_WRITER: usize = 150;
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut workers = Vec::new();
    for t in 0..WRITERS {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let mut c = SketchClient::connect(&addr).unwrap();
            let mut g = Pcg64::new(t as u64, 9);
            for i in 0..PER_WRITER {
                c.register_in(None, &format!("r{t}-{i}"), vec_of(&mut g, 16)).unwrap();
                if i % 5 == 0 {
                    c.knn_in(None, vec_of(&mut g, 16), 3).unwrap();
                }
            }
        }));
    }

    let mut readers = Vec::new();
    for _ in 0..2 {
        let addr = addr.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || {
            let mut c = SketchClient::connect(&addr).unwrap();
            let mut snapshots = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                for max in [0u32, 7, 1000] {
                    let entries = c.slow_queries(max).unwrap();
                    assert!(entries.len() <= 128, "ring overflowed its cap");
                    if max > 0 {
                        assert!(entries.len() <= max as usize);
                    }
                    for pair in entries.windows(2) {
                        assert!(
                            pair[0].seq < pair[1].seq,
                            "snapshot out of order: {} then {}",
                            pair[0].seq,
                            pair[1].seq
                        );
                    }
                    for e in &entries {
                        // A torn entry would surface as an empty label
                        // or a zeroed timing on a 1 us threshold.
                        // Writers send register/knn; the readers' own
                        // SlowQueries polls land as admin entries.
                        assert!(
                            matches!(e.kind.as_str(), "register" | "knn" | "admin"),
                            "unexpected kind {:?}",
                            e.kind
                        );
                        assert_eq!(e.collection, "default");
                        assert!(e.total_us >= 1, "slow entry with zero duration");
                    }
                }
                snapshots += 1;
            }
            snapshots
        }));
    }

    for w in workers {
        w.join().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for r in readers {
        assert!(r.join().unwrap() > 0, "reader never snapshotted the ring");
    }

    // Quiesced: the ring holds exactly the cap (writers pushed far more
    // than 128), the tail is the freshest entry, and a bounded fetch
    // returns the tail of the full fetch.
    let mut c = SketchClient::connect(&addr).unwrap();
    let all = c.slow_queries(0).unwrap();
    assert_eq!(all.len(), 128, "ring must sit exactly at its cap");
    // The full fetch above is itself a slow admin request by the time
    // the next frame is handled, so the bounded fetch sees the ring
    // shifted by exactly one: two old entries plus that admin entry.
    let last_3 = c.slow_queries(3).unwrap();
    assert_eq!(last_3.len(), 3);
    assert_eq!(&last_3[..2], &all[all.len() - 2..]);
    assert_eq!(last_3[2].kind, "admin");
    assert_eq!(last_3[2].seq, all[all.len() - 1].seq + 1);
}
